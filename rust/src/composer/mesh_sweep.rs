//! The canonical mesh-shape sweep behind `benches/bench_mesh.rs` and the
//! CI bench-regression gate.
//!
//! One function ([`mesh_sweep_points`]) computes the
//! step-time-vs-mesh-shape table — every 5-axis `data × pipeline × fsdp
//! × model × expert` factorization the bench reports for a fixed
//! 256-chip H100 budget, with the collective schedule's comm costs, the
//! pipeline bubble, and the MoE AllToAll dispatch cost per point.  Three
//! consumers share it, which is the point:
//!
//! * `rust/benches/bench_mesh.rs` prints the table and emits the JSON
//!   artifact;
//! * `rust/src/bin/bench_check.rs` recomputes the points and fails CI
//!   when they drift from the committed `benches/baseline.json` beyond a
//!   tolerance;
//! * `rust/tests/bench_gate.rs` proves the comparison mechanism catches
//!   injected regressions, in tier-1.
//!
//! Everything here is pure f64 cost-model arithmetic — deterministic
//! across runs, so the gate's tolerance only has to absorb genuine
//! model changes, never noise.

use crate::perfmodel::chips;
use crate::perfmodel::estimator::SystemProfile;
use crate::perfmodel::{Strategy, TransformerShape};
use crate::util::json::Json;

use super::cost::{evaluate_candidate, CostModel};

/// Chip budget every factorization must use exactly.
pub const SWEEP_CHIPS: usize = 256;
/// Global batch (sequences) of the swept workload.
pub const SWEEP_GLOBAL_BATCH: usize = 1024;
/// Sequence length of the swept workload.
pub const SWEEP_SEQ: usize = 4096;
/// Microbatches for the pipelined shapes (1F1B).
pub const SWEEP_MICROBATCHES: usize = 16;

/// One mesh shape's worth of sweep output.
#[derive(Clone, Debug)]
pub struct MeshSweepPoint {
    /// `"dxpxfxmxe"` — the gate's join key.
    pub mesh: String,
    pub data: usize,
    pub pipeline: usize,
    pub fsdp: usize,
    pub model: usize,
    pub expert: usize,
    pub microbatches: usize,
    /// Whether the point ran the MoE model variant (every `expert > 1`
    /// shape does).
    pub moe: bool,
    /// Whether the plan fit in HBM (`false` = the estimator's OOM row).
    pub fits: bool,
    /// Pipeline bubble fraction off the 1F1B slot grid.
    pub bubble: f64,
    /// Roofline compute estimate (0 when OOM).
    pub compute_s: f64,
    /// Schedule totals over the H100 interconnect.
    pub comm_s: f64,
    pub exposed_comm_s: f64,
    /// Summed cost of the schedule's `AllToAll` entries (0 without an
    /// expert axis).
    pub alltoall_s: f64,
    /// The estimator's analytic expert-dispatch cost
    /// (`4 · layers_resident · hierarchical(AllToAll, tok_bytes, e)`);
    /// the bench asserts `alltoall_s` equals this exactly.
    pub alltoall_analytic_s: f64,
    /// Composed step time (0 when OOM).
    pub step_s: f64,
    pub schedule_entries: usize,
    /// Total simulated comm time of the schedule executed by the flow
    /// simulator ([`crate::netsim`]) over a two-tier pod/spine topology
    /// of [`SWEEP_CHIPS`] hosts — topology- and contention-aware, where
    /// `comm_s` is the closed-form analytic total.
    pub netsim_tiered_s: f64,
    /// Simulated comm time on the critical path (non-overlappable
    /// entries), same topology.
    pub netsim_exposed_s: f64,
}

/// The swept factorizations: `(data, pipeline, fsdp, model, expert)`,
/// each multiplying out to [`SWEEP_CHIPS`].  Dense rows tell the §3
/// story (pure DP OOMs, FSDP fits, TP pays exposed reductions, pipeline
/// trades a bubble); the `expert > 1` rows run the MoE variant and
/// exercise the AllToAll dispatch cost.
pub const SWEEP_MESHES: [(usize, usize, usize, usize, usize); 14] = [
    (256, 1, 1, 1, 1), // pure DP: must OOM (14 bytes/param unsharded)
    (32, 1, 8, 1, 1),
    (8, 1, 32, 1, 1),
    (4, 1, 64, 1, 1),
    (1, 1, 256, 1, 1), // pure FSDP
    (8, 1, 16, 2, 1),
    (4, 1, 8, 8, 1),
    (1, 1, 32, 8, 1), // TP-heavy
    (1, 4, 64, 1, 1), // pipeline × FSDP
    (4, 8, 8, 1, 1),  // pipeline-heavy
    (1, 4, 8, 8, 1),  // pipeline × FSDP × TP
    (4, 1, 8, 1, 8),  // DP × FSDP × EP (MoE)
    (1, 1, 32, 1, 8), // FSDP × EP (MoE)
    (1, 4, 8, 1, 8),  // PP × FSDP × EP (MoE)
];

/// The dense model of the sweep (Table-3 row 1).
pub fn sweep_shape_dense() -> TransformerShape {
    TransformerShape::llama2_7b()
}

/// The MoE variant the `expert > 1` rows run: the same backbone with an
/// 8-expert top-2 FFN bank (one expert per rank at `expert = 8`).
pub fn sweep_shape_moe() -> TransformerShape {
    let mut s = sweep_shape_dense();
    s.name = "Llama2-7B-MoE8".into();
    s.num_experts = 8;
    s.active_experts = 2;
    s
}

/// Compute the full sweep.  Panics on an estimator error that is not an
/// OOM row — in this table only OOM is a legitimate infeasibility.
///
/// The per-row cost arithmetic is [`super::cost::evaluate_candidate`] —
/// the *same* function the planner's branch-and-bound leaves call, so
/// the sweep's columns and the planner's columns cannot drift apart
/// (`rust/tests/planner_suite.rs` pins them bit-equal).
pub fn mesh_sweep_points() -> Vec<MeshSweepPoint> {
    let chip = chips::h100();
    let profile = SystemProfile::axlearn();
    let model = CostModel::new(&chip, &profile, SWEEP_GLOBAL_BATCH, SWEEP_SEQ);
    // the topology-aware re-ranker: the same schedule, executed by the
    // flow simulator over an explicit two-tier pod/spine fabric
    let topo = crate::netsim::Topology::two_tier(SWEEP_CHIPS, &chip.interconnect);
    let mut points = Vec::with_capacity(SWEEP_MESHES.len());
    for (d, p, f, m, e) in SWEEP_MESHES {
        assert_eq!(d * p * f * m * e, SWEEP_CHIPS, "factorization must use the full budget");
        let shape = if e > 1 { sweep_shape_moe() } else { sweep_shape_dense() };
        let strat = Strategy {
            data: d,
            fsdp: f,
            tensor: m,
            pipeline: p,
            expert: e,
            microbatches: if p > 1 { SWEEP_MICROBATCHES } else { 1 },
        };
        let mesh = format!("{d}x{p}x{f}x{m}x{e}");
        let eval = evaluate_candidate(&model, &shape, &strat, "auto")
            .unwrap_or_else(|err| panic!("only OOM is acceptable here ({mesh}): {err:#}"));
        let sim = eval
            .schedule
            .simulate(&topo, crate::netsim::AlgoChoice::Auto)
            .unwrap_or_else(|err| panic!("netsim failed for mesh {mesh}: {err:#}"));
        let c = eval.cost;
        points.push(MeshSweepPoint {
            mesh,
            data: d,
            pipeline: p,
            fsdp: f,
            model: m,
            expert: e,
            microbatches: c.microbatches,
            moe: e > 1,
            fits: c.fits,
            bubble: c.bubble,
            compute_s: c.compute_s,
            comm_s: c.comm_s,
            exposed_comm_s: c.exposed_comm_s,
            alltoall_s: c.alltoall_s,
            alltoall_analytic_s: c.alltoall_analytic_s,
            step_s: c.step_s,
            schedule_entries: c.schedule_entries,
            netsim_tiered_s: sim.total_sim_s(),
            netsim_exposed_s: sim.exposed_sim_s(),
        });
    }
    points
}

/// The bench/baseline JSON document for a computed sweep (the same
/// format `bench_mesh` prints and `benches/baseline.json` commits).
pub fn mesh_sweep_doc(points: &[MeshSweepPoint]) -> Json {
    let best = points
        .iter()
        .filter(|p| p.fits)
        .min_by(|a, b| a.step_s.total_cmp(&b.step_s))
        .expect("at least one feasible mesh");
    Json::obj(vec![
        ("bench", Json::str("mesh_step_time")),
        ("chip", Json::str("H100")),
        ("chips", Json::num(SWEEP_CHIPS as f64)),
        ("model", Json::str(sweep_shape_dense().name)),
        ("moe_model", Json::str(sweep_shape_moe().name)),
        ("global_batch", Json::num(SWEEP_GLOBAL_BATCH as f64)),
        ("seq_len", Json::num(SWEEP_SEQ as f64)),
        ("microbatches", Json::num(SWEEP_MICROBATCHES as f64)),
        ("best_mesh", Json::str(best.mesh.clone())),
        (
            // provenance of the netsim_* columns: the flow simulator's
            // topology and lowering (docs/netsim.md)
            "netsim",
            Json::obj(vec![
                ("topology", Json::str("two_tier")),
                ("hosts", Json::num(SWEEP_CHIPS as f64)),
                ("algo", Json::str("auto")),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("mesh", Json::str(p.mesh.clone())),
                            ("data", Json::num(p.data as f64)),
                            ("pipeline", Json::num(p.pipeline as f64)),
                            ("fsdp", Json::num(p.fsdp as f64)),
                            ("model", Json::num(p.model as f64)),
                            ("expert", Json::num(p.expert as f64)),
                            ("microbatches", Json::num(p.microbatches as f64)),
                            ("moe", Json::Bool(p.moe)),
                            ("fits", Json::Bool(p.fits)),
                            ("bubble", Json::num(p.bubble)),
                            ("compute_s", Json::num(p.compute_s)),
                            ("comm_s", Json::num(p.comm_s)),
                            ("exposed_comm_s", Json::num(p.exposed_comm_s)),
                            ("alltoall_s", Json::num(p.alltoall_s)),
                            ("step_s", Json::num(p.step_s)),
                            ("netsim_tiered_s", Json::num(p.netsim_tiered_s)),
                            ("netsim_exposed_s", Json::num(p.netsim_exposed_s)),
                            ("schedule_entries", Json::num(p.schedule_entries as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Default relative drift tolerance of the gate.  Wide enough to absorb
/// libm-level noise across toolchains (the arithmetic itself is
/// deterministic), tight enough that any real cost-model change trips
/// it — at which point the baseline is regenerated *deliberately* with
/// `bench_check --write` and reviewed in the diff.
pub const BASELINE_DEFAULT_TOL: f64 = 1e-3;

pub(crate) fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    let scale = a.abs().max(b.abs());
    (a - b).abs() <= tol * scale.max(1e-12)
}

/// Compare a computed sweep against a baseline document.  Returns one
/// human-readable message per drifted/missing/extra metric; empty means
/// the gate passes.  `tol` is relative (see [`BASELINE_DEFAULT_TOL`]).
pub fn compare_to_baseline(points: &[MeshSweepPoint], baseline: &Json, tol: f64) -> Vec<String> {
    let mut drifts = Vec::new();
    let Some(base_points) = baseline.get("points").and_then(|p| p.as_arr()) else {
        return vec!["baseline has no \"points\" array".into()];
    };
    for p in points {
        let Some(b) = base_points
            .iter()
            .find(|b| b.get("mesh").and_then(|m| m.as_str()) == Some(p.mesh.as_str()))
        else {
            drifts.push(format!("mesh {} missing from baseline", p.mesh));
            continue;
        };
        let fits = b.get("fits").and_then(|f| f.as_bool());
        if fits != Some(p.fits) {
            drifts.push(format!(
                "mesh {}: fits changed {:?} -> {} (an OOM row appeared or vanished)",
                p.mesh, fits, p.fits
            ));
            continue;
        }
        for (metric, current) in [
            ("bubble", p.bubble),
            ("compute_s", p.compute_s),
            ("comm_s", p.comm_s),
            ("exposed_comm_s", p.exposed_comm_s),
            ("alltoall_s", p.alltoall_s),
            ("step_s", p.step_s),
            ("netsim_tiered_s", p.netsim_tiered_s),
            ("netsim_exposed_s", p.netsim_exposed_s),
        ] {
            match b.get(metric).and_then(|v| v.as_f64()) {
                None => drifts.push(format!("mesh {}: baseline lacks {metric}", p.mesh)),
                Some(base) if !rel_close(current, base, tol) => drifts.push(format!(
                    "mesh {}: {metric} drifted {base:.6e} -> {current:.6e} \
                     ({:+.3}% > {:.3}% tolerance)",
                    p.mesh,
                    (current - base) / base.abs().max(1e-12) * 100.0,
                    tol * 100.0,
                )),
                Some(_) => {}
            }
        }
    }
    for b in base_points {
        let name = b.get("mesh").and_then(|m| m.as_str()).unwrap_or("<unnamed>");
        if !points.iter().any(|p| p.mesh == name) {
            drifts.push(format!("baseline mesh {name} no longer swept"));
        }
    }
    drifts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_the_story() {
        let points = mesh_sweep_points();
        assert_eq!(points.len(), SWEEP_MESHES.len());
        // pure DP OOMs; most sharded meshes fit
        assert!(!points[0].fits, "pure DP of a 7B model must OOM");
        assert!(points.iter().filter(|p| p.fits).count() >= 9);
        // every expert row prices its AllToAll exactly at the analytic
        // estimator formula — the consistency the gate guards
        for p in &points {
            if p.expert > 1 {
                assert!(p.moe && p.alltoall_s > 0.0, "{}", p.mesh);
                assert_eq!(
                    p.alltoall_s, p.alltoall_analytic_s,
                    "{}: schedule and estimator disagree on the AllToAll cost",
                    p.mesh
                );
            } else {
                assert_eq!(p.alltoall_s, 0.0, "{}", p.mesh);
            }
        }
        // pipelined rows carry their bubble
        for p in &points {
            assert_eq!(p.bubble > 0.0, p.pipeline > 1, "{}", p.mesh);
        }
        // the simulated columns exist wherever the analytic model
        // prices communication, and exposed <= total
        for p in &points {
            assert_eq!(p.netsim_tiered_s > 0.0, p.comm_s > 0.0, "{}", p.mesh);
            assert!(p.netsim_exposed_s <= p.netsim_tiered_s + 1e-12, "{}", p.mesh);
            assert_eq!(p.netsim_exposed_s > 0.0, p.exposed_comm_s > 0.0, "{}", p.mesh);
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = mesh_sweep_points();
        let b = mesh_sweep_points();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mesh, y.mesh);
            assert_eq!(x.step_s.to_bits(), y.step_s.to_bits());
            assert_eq!(x.comm_s.to_bits(), y.comm_s.to_bits());
            assert_eq!(x.netsim_tiered_s.to_bits(), y.netsim_tiered_s.to_bits());
            assert_eq!(x.netsim_exposed_s.to_bits(), y.netsim_exposed_s.to_bits());
        }
    }

    // (the self-comparison and injected-regression scenarios live in
    // tier-1 `rust/tests/bench_gate.rs`, which also exercises the
    // committed baseline file; only the structural cases it does not
    // cover are tested here)

    #[test]
    fn structural_drift_is_caught() {
        let points = mesh_sweep_points();
        let parsed = Json::parse(&mesh_sweep_doc(&points).to_string()).unwrap();
        // a vanished mesh
        let fewer = &points[1..];
        assert!(compare_to_baseline(fewer, &parsed, BASELINE_DEFAULT_TOL)
            .iter()
            .any(|d| d.contains("no longer swept")));
        // an OOM flip
        let mut flipped = points.clone();
        flipped[0].fits = true;
        assert!(compare_to_baseline(&flipped, &parsed, BASELINE_DEFAULT_TOL)
            .iter()
            .any(|d| d.contains("fits changed")));
        // a garbage baseline
        assert!(!compare_to_baseline(&points, &Json::Null, BASELINE_DEFAULT_TOL).is_empty());
    }
}
