//! Static schedule verifier: prove a lowered [`CollectiveSchedule`] and
//! its pipeline P2P program deadlock-free, well-formed, and memory-safe
//! *before* a single simulated byte moves.
//!
//! The AOT compile-check (`aot_check.rs`) bounds memory; this pass
//! closes the other half of the §4.2 promise by type-checking the
//! *communication program* itself — the same spirit as GSPMD's
//! partitioner validating the sharded program before execution.  Five
//! check classes, each with a stable [`CheckId`] diagnostic name:
//!
//! * **subgroup-tiling** — every collective's `group × count` subgroups
//!   are disjoint and tile the device grid along the named mesh axis
//!   (coalesced instances — `count` dividing the tile count — are the
//!   one sanctioned exception, used by the mesh trainer's replicated
//!   gradient sync).
//! * **phase-order** — no `Gather`-phase consumer precedes its
//!   producer: all-gathers belong to `Gather`, reduce-scatters to
//!   `Update`, reductions/dispatch never to `Gather`, and the entry
//!   list itself is phase-monotone.
//! * **payload-conservation** — payloads are finite and positive,
//!   gather/scatter payloads divide by the subgroup size (exact
//!   lowered schedules), paired all-gather/reduce-scatter entries move
//!   the same bytes, and AllToAll dispatch/combine bucket totals are
//!   preserved per axis.
//! * **p2p-unmatched** / **p2p-deadlock** — the pipeline send/recv
//!   program is lowered to an explicit op list ([`lower_p2p_program`],
//!   the same per-microbatch channel protocol the mesh trainer
//!   executes) and checked: every recv has a matching send *already
//!   issued* under the sequential executor, no sends are left pending
//!   after the step (the runtime's `pending_p2p` drain assert, ahead
//!   of time), and the cross-stage wait-for graph is acyclic.
//! * **watermark** — a live-buffer high-watermark derived from entry
//!   lifetimes (gathered parameter blocks live through compute, plus
//!   the largest transient), cross-checked against the `aot_check`
//!   HBM bound so the two static reports cannot silently disagree.
//!
//! Wired in three places: [`crate::distributed::mesh::MeshTrainer`]
//! refuses to construct or initialize over a schedule that does not
//! lint clean (the `verify` knob), [`verify_plan`] lints any
//! materialized [`Plan`], and the `verify` binary + `bench_check` lint
//! every mesh-rules preset and the canonical 14-point sweep in CI.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::Result;

use crate::perfmodel::chips;
use crate::perfmodel::comms::Collective;
use crate::perfmodel::Strategy;
use crate::util::json::Json;

use super::aot_check::aot_compile_check;
use super::mesh_sweep::{
    sweep_shape_dense, sweep_shape_moe, SWEEP_GLOBAL_BATCH, SWEEP_MESHES, SWEEP_MICROBATCHES,
    SWEEP_SEQ,
};
use super::plan::Plan;
use super::schedule::{build_schedule, CollectiveSchedule, PipelineSchedule, SchedulePhase};
use super::sharding::shard_axes_from_specs;

/// Stable identifier of a verifier check class; `name()` is the string
/// diagnostics carry in reports, tests, and the JSON lint artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CheckId {
    /// Subgroups overlap, miss devices, or sit on an unknown/degenerate
    /// mesh axis.
    SubgroupTiling,
    /// An entry's phase is illegal for its collective, or the entry
    /// list is not phase-monotone.
    PhaseOrder,
    /// Payload bytes are malformed, gather/scatter payloads don't
    /// divide, or AllToAll bucket totals leak.
    PayloadConservation,
    /// A recv with no send, or sends left pending after the step.
    P2pUnmatched,
    /// A recv whose matching send the executor would never reach.
    P2pDeadlock,
    /// The schedule's live-buffer high-watermark exceeds the HBM bound
    /// the AOT check approved.
    Watermark,
}

impl CheckId {
    /// The diagnostic catalogue name (`docs/verifier.md`).
    pub fn name(self) -> &'static str {
        match self {
            CheckId::SubgroupTiling => "subgroup-tiling",
            CheckId::PhaseOrder => "phase-order",
            CheckId::PayloadConservation => "payload-conservation",
            CheckId::P2pUnmatched => "p2p-unmatched",
            CheckId::P2pDeadlock => "p2p-deadlock",
            CheckId::Watermark => "watermark",
        }
    }
}

/// One verifier finding: which check, which schedule entry (when the
/// finding anchors to one), which mesh axis, and a human message that
/// always names the entry index and axis when known.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub check: CheckId,
    /// Index into `schedule.entries` when the finding anchors to one.
    pub entry: Option<usize>,
    /// Mesh axis the finding concerns ("-" for program-level findings).
    pub axis: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.check.name(), self.message)
    }
}

fn diag(check: CheckId, entry: Option<usize>, axis: &str, message: String) -> Diagnostic {
    Diagnostic { check, entry, axis: axis.to_string(), message }
}

/// What the verifier knows about the mesh a schedule was lowered for.
#[derive(Clone, Debug)]
pub struct VerifyContext {
    /// The resolved parallelism strategy (device grid + axis degrees).
    pub strategy: Strategy,
    /// Mesh axes that shard parameters (drives the expected fsdp/model
    /// subgroup sizes via [`super::schedule::shard_degrees`]).
    pub shard_axes: Vec<String>,
    /// Whether payload bytes are exact integers (the mesh trainer's
    /// lowered schedules) rather than analytic estimates (plan-level
    /// schedules); enables the gather/scatter divisibility check.
    pub exact_payloads: bool,
    /// Per-chip HBM capacity when the target chip is known.
    pub hbm_capacity: Option<f64>,
    /// The AOT check's verdict for the same plan, when one ran; the
    /// watermark check cross-references it so the two reports agree.
    pub aot_fits: Option<bool>,
}

impl VerifyContext {
    /// A context for a bare strategy with every axis sharding params
    /// and no memory information.
    pub fn for_strategy(strategy: &Strategy) -> Self {
        VerifyContext {
            strategy: strategy.clone(),
            shard_axes: vec!["fsdp".into(), "model".into()],
            exact_payloads: false,
            hbm_capacity: None,
            aot_fits: None,
        }
    }
}

/// The verifier's answer for one schedule.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
    /// Entries inspected.
    pub entries: usize,
    /// Live-buffer high-watermark the watermark check derived
    /// (0 when the schedule is empty).
    pub watermark_bytes: f64,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable summary, one diagnostic per line.
    pub fn render(&self) -> String {
        if self.is_clean() {
            return format!("verify: OK ({} entries)", self.entries);
        }
        let mut out = format!(
            "verify: {} diagnostic(s) over {} entries:\n",
            self.diagnostics.len(),
            self.entries
        );
        for d in &self.diagnostics {
            out.push_str(&format!("  {d}\n"));
        }
        out
    }
}

/// Expected subgroup size along a named mesh axis, `None` for an axis
/// the strategy does not know.
fn expected_group(ctx: &VerifyContext, axis: &str) -> Option<usize> {
    let (fs, ms, rep) = super::schedule::shard_degrees(&ctx.strategy, &ctx.shard_axes);
    match axis {
        "fsdp" => Some(fs),
        "model" | "tensor" => Some(ms),
        "data" => Some(rep),
        "pipeline" => Some(ctx.strategy.pipeline.max(1)),
        "expert" => Some(ctx.strategy.expert.max(1)),
        _ => None,
    }
}

/// Statically verify one collective schedule against its mesh context.
///
/// `pipeline` (when given) enables the entry-level P2P presence checks;
/// the program-level send/recv analysis is [`verify_pipeline`] (the
/// two compose in [`verify_plan`]).
///
/// Diagnostics are precise in the single-mutation sense the property
/// suite relies on: a per-entry failure short-circuits that entry's
/// remaining checks, and cross-entry checks skip axes that already
/// carry a finding, so corrupting one field yields exactly one
/// diagnostic naming the entry index and axis.
pub fn verify_schedule(
    sched: &CollectiveSchedule,
    pipeline: Option<&PipelineSchedule>,
    ctx: &VerifyContext,
) -> VerifyReport {
    let devices = ctx.strategy.total_chips().max(1);
    let mut diags: Vec<Diagnostic> = Vec::new();
    // entries that passed every per-entry check; cross-entry checks run
    // only over these
    let mut clean: Vec<usize> = Vec::new();

    for (i, e) in sched.entries.iter().enumerate() {
        // (a) subgroup well-formedness -----------------------------------
        let Some(expect) = expected_group(ctx, &e.axis) else {
            diags.push(diag(
                CheckId::SubgroupTiling,
                Some(i),
                &e.axis,
                format!(
                    "entry {i} ({:?} {:?}): unknown mesh axis \"{}\" \
                     (mesh knows data/pipeline/fsdp/model/expert)",
                    e.collective, e.tensor, e.axis
                ),
            ));
            continue;
        };
        if expect < 2 {
            diags.push(diag(
                CheckId::SubgroupTiling,
                Some(i),
                &e.axis,
                format!(
                    "entry {i} ({:?} {:?}): collective over axis \"{}\" whose mesh degree \
                     is {expect} — a degenerate subgroup communicates with nobody",
                    e.collective, e.tensor, e.axis
                ),
            ));
            continue;
        }
        if e.group != expect {
            diags.push(diag(
                CheckId::SubgroupTiling,
                Some(i),
                &e.axis,
                format!(
                    "entry {i} ({:?} {:?}): subgroup size {} does not match the \
                     axis \"{}\" degree {expect}",
                    e.collective, e.tensor, e.group, e.axis
                ),
            ));
            continue;
        }
        if devices % e.group != 0 {
            diags.push(diag(
                CheckId::SubgroupTiling,
                Some(i),
                &e.axis,
                format!(
                    "entry {i}: subgroups of {} along axis \"{}\" cannot tile a \
                     {devices}-device grid",
                    e.group, e.axis
                ),
            ));
            continue;
        }
        let tiles = devices / e.group;
        if e.count == 0 || e.count > tiles || tiles % e.count != 0 {
            diags.push(diag(
                CheckId::SubgroupTiling,
                Some(i),
                &e.axis,
                format!(
                    "entry {i}: {} subgroup instance(s) of size {} along axis \"{}\" \
                     {} the {devices}-device grid (expected {tiles}, or a divisor \
                     for coalesced instances)",
                    e.count,
                    e.group,
                    e.axis,
                    if e.count > tiles { "overlap on" } else { "do not tile" },
                ),
            ));
            continue;
        }

        // (c) payload well-formedness ------------------------------------
        if !e.bytes.is_finite() || e.bytes <= 0.0 || !e.cost_s.is_finite() || e.cost_s < 0.0 {
            diags.push(diag(
                CheckId::PayloadConservation,
                Some(i),
                &e.axis,
                format!(
                    "entry {i} ({:?} {:?}) on axis \"{}\": malformed payload \
                     (bytes {:e}, cost {:e}s) — payloads must be finite and positive",
                    e.collective, e.tensor, e.axis, e.bytes, e.cost_s
                ),
            ));
            continue;
        }

        // (d) phase legality per collective ------------------------------
        let phase_bad = match e.collective {
            Collective::AllGather => e.phase != SchedulePhase::Gather,
            Collective::ReduceScatter => e.phase != SchedulePhase::Update,
            _ => e.phase == SchedulePhase::Gather,
        };
        if phase_bad {
            diags.push(diag(
                CheckId::PhaseOrder,
                Some(i),
                &e.axis,
                format!(
                    "entry {i} ({:?} {:?}) on axis \"{}\": illegal phase {:?} — \
                     all-gathers reconstruct params in Gather, reduce-scatters \
                     follow the backward in Update, and reductions/dispatch \
                     consume computed values so cannot run in Gather",
                    e.collective, e.tensor, e.axis, e.phase
                ),
            ));
            continue;
        }

        // (c) gather/scatter divisibility (exact lowered payloads only) --
        if ctx.exact_payloads
            && matches!(e.collective, Collective::AllGather | Collective::ReduceScatter)
        {
            let words = e.bytes / 4.0;
            let whole = words.fract() == 0.0;
            if !whole || (words as u64) % (e.group as u64) != 0 {
                diags.push(diag(
                    CheckId::PayloadConservation,
                    Some(i),
                    &e.axis,
                    format!(
                        "entry {i} ({:?} {:?}): payload {} bytes on axis \"{}\" is not \
                         an equal split over the {}-rank subgroup (must be a whole \
                         multiple of 4·group bytes)",
                        e.collective, e.tensor, e.bytes, e.axis, e.group
                    ),
                ));
                continue;
            }
        }

        clean.push(i);
    }

    // axes already carrying a finding are excluded from cross-entry
    // checks: one corrupted field must yield exactly one diagnostic
    let tainted: Vec<String> = diags.iter().map(|d| d.axis.clone()).collect();
    let is_clean_axis = |axis: &str| !tainted.iter().any(|a| a == axis);

    // (d) the issue order itself must be phase-monotone ------------------
    let mut prev: Option<(usize, SchedulePhase)> = None;
    for &i in &clean {
        let e = &sched.entries[i];
        if let Some((pi, pp)) = prev {
            if e.phase < pp && is_clean_axis(&e.axis) {
                diags.push(diag(
                    CheckId::PhaseOrder,
                    Some(i),
                    &e.axis,
                    format!(
                        "entry {i} ({:?} on axis \"{}\", phase {:?}) is issued after \
                         entry {pi} (phase {pp:?}) — the schedule is not phase-monotone, \
                         a Gather-phase consumer would precede its producer",
                        e.collective, e.axis, e.phase
                    ),
                ));
                break; // one finding for the ordering, not a cascade
            }
        }
        prev = Some((i, e.phase));
    }

    // (c) paired all-gather / reduce-scatter payload equality ------------
    // key: (axis, tensor) — the mesh trainer pairs per-tensor (exact
    // payloads); the plan-level schedule pairs "params"/"grads",
    // normalized to one key below
    let exact = ctx.exact_payloads;
    let norm = move |t: &str| match t {
        "params" | "grads" if !exact => "params+grads".to_string(),
        other => other.to_string(),
    };
    let mut gathers: BTreeMap<(String, String), (usize, f64)> = BTreeMap::new();
    for &i in &clean {
        let e = &sched.entries[i];
        if e.collective == Collective::AllGather {
            gathers.insert((e.axis.clone(), norm(&e.tensor)), (i, e.bytes));
        }
    }
    for &i in &clean {
        let e = &sched.entries[i];
        if e.collective != Collective::ReduceScatter || !is_clean_axis(&e.axis) {
            continue;
        }
        if let Some(&(gi, gbytes)) = gathers.get(&(e.axis.clone(), norm(&e.tensor))) {
            if e.bytes != gbytes {
                diags.push(diag(
                    CheckId::PayloadConservation,
                    Some(i),
                    &e.axis,
                    format!(
                        "entry {i} (ReduceScatter {:?}) on axis \"{}\" moves {} bytes but \
                         its paired AllGather (entry {gi}) moves {gbytes} — the gathered \
                         and re-scattered partitions must conserve bytes",
                        e.tensor, e.axis, e.bytes
                    ),
                ));
            }
        }
    }

    // (c) AllToAll bucket conservation per axis --------------------------
    let mut a2a: BTreeMap<String, (f64, f64, Option<usize>, usize)> = BTreeMap::new();
    for &i in &clean {
        let e = &sched.entries[i];
        if e.collective != Collective::AllToAll || !is_clean_axis(&e.axis) {
            continue;
        }
        let slot = a2a.entry(e.axis.clone()).or_insert((0.0, 0.0, None, 0));
        if e.tensor.contains("combine") {
            slot.1 += e.bytes;
            slot.2 = Some(i);
        } else {
            slot.0 += e.bytes; // dispatch side
        }
        slot.3 += 1;
    }
    for (axis, (dispatch, combine, combine_entry, n)) in &a2a {
        if *n < 2 {
            diags.push(diag(
                CheckId::PayloadConservation,
                *combine_entry,
                axis,
                format!(
                    "axis \"{axis}\": unpaired AllToAll — token dispatch and combine \
                     must both appear ({n} entry present)"
                ),
            ));
        } else if dispatch != combine {
            diags.push(diag(
                CheckId::PayloadConservation,
                *combine_entry,
                axis,
                format!(
                    "entry {} on axis \"{axis}\": AllToAll bucket totals leak — dispatch \
                     moves {dispatch} bytes but combine returns {combine}",
                    combine_entry.map(|i| i.to_string()).unwrap_or_else(|| "?".into()),
                ),
            ));
        }
    }

    // (b) entry-level P2P presence vs the pipeline grid ------------------
    if let Some(pipe) = pipeline {
        if is_clean_axis("pipeline") {
            let p2p: Vec<usize> = clean
                .iter()
                .copied()
                .filter(|&i| sched.entries[i].collective == Collective::P2P)
                .collect();
            if pipe.stages <= 1 {
                if let Some(&i) = p2p.first() {
                    let e = &sched.entries[i];
                    diags.push(diag(
                        CheckId::P2pUnmatched,
                        Some(i),
                        &e.axis,
                        format!(
                            "entry {i} (P2P {:?}) on axis \"{}\": stage-boundary transfer \
                             in a 1-stage pipeline — every send would wait on a peer that \
                             does not exist",
                            e.tensor, e.axis
                        ),
                    ));
                }
            } else if p2p.is_empty() {
                diags.push(diag(
                    CheckId::P2pUnmatched,
                    None,
                    "pipeline",
                    format!(
                        "axis \"pipeline\": a {}-stage pipeline lowered no P2P entries — \
                         stage boundaries would starve",
                        pipe.stages
                    ),
                ));
            }
        }
    }

    // (e) live-buffer high-watermark vs the AOT HBM bound ----------------
    let mut watermark = 0.0f64;
    let mut transient = 0.0f64;
    for &i in &clean {
        let e = &sched.entries[i];
        if e.phase == SchedulePhase::Gather {
            // gathered parameter blocks stay live through compute
            watermark += e.bytes;
        } else {
            transient = transient.max(e.bytes);
        }
    }
    watermark += transient;
    if let Some(hbm) = ctx.hbm_capacity {
        // aot_fits == Some(false) means both reports already agree the
        // plan is infeasible; a diagnostic would be noise
        if ctx.aot_fits != Some(false) && watermark > hbm {
            diags.push(diag(
                CheckId::Watermark,
                None,
                "-",
                format!(
                    "live-buffer high-watermark {watermark:.3e} bytes exceeds the \
                     {hbm:.3e}-byte HBM bound{}",
                    if ctx.aot_fits == Some(true) {
                        " the AOT check approved — the two static reports disagree"
                    } else {
                        ""
                    }
                ),
            ));
        }
    }

    VerifyReport { diagnostics: diags, entries: sched.entries.len(), watermark_bytes: watermark }
}

// ---------------------------------------------------------------------------
// P2P program analysis
// ---------------------------------------------------------------------------

/// Channel tag of microbatch `j`'s forward (activation) transfers — the
/// canonical definition the mesh trainer's executor shares.
pub fn fwd_channel_tag(microbatch: usize) -> u64 {
    microbatch as u64
}

/// Channel tag of microbatch `j`'s backward (gradient) transfers; the
/// high bit block keeps the two directions' channels disjoint.
pub fn bwd_channel_tag(microbatch: usize) -> u64 {
    (1u64 << 32) | microbatch as u64
}

/// One send or recv in the lowered pipeline program, attributed to the
/// stage that issues it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct P2pOp {
    /// Stage whose program this op belongs to.
    pub stage: usize,
    /// `true` = send (non-blocking, buffers into the channel);
    /// `false` = recv (blocks until a matching send was issued).
    pub is_send: bool,
    /// Sending stage of the channel.
    pub src: usize,
    /// Receiving stage of the channel.
    pub dst: usize,
    /// Channel tag ([`fwd_channel_tag`] / [`bwd_channel_tag`]).
    pub tag: u64,
}

impl fmt::Display for P2pOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}(stage {}: {}->{} tag {:#x})",
            if self.is_send { "send" } else { "recv" },
            self.stage,
            self.src,
            self.dst,
            self.tag
        )
    }
}

/// Lower a pipeline grid to its explicit send/recv program, in the
/// execution order the mesh trainer walks: forward slots in stored slot
/// order (recv-before-forward, send-after), then backward slots
/// (recv-before-backward, send-after).  This is the program
/// [`verify_p2p_program`] analyzes — and, by construction, exactly the
/// channel protocol `MeshTrainer` executes, so a clean verdict here is
/// a clean `pending_p2p` drain at runtime.
pub fn lower_p2p_program(pipe: &PipelineSchedule) -> Vec<P2pOp> {
    let s_n = pipe.stages;
    let mut ops = Vec::new();
    if s_n <= 1 {
        return ops;
    }
    for sl in pipe.slots.iter().filter(|sl| sl.is_forward) {
        let (st, j) = (sl.stage, sl.microbatch);
        if st > 0 {
            ops.push(P2pOp { stage: st, is_send: false, src: st - 1, dst: st, tag: fwd_channel_tag(j) });
        }
        if st < s_n - 1 {
            ops.push(P2pOp { stage: st, is_send: true, src: st, dst: st + 1, tag: fwd_channel_tag(j) });
        }
    }
    for sl in pipe.slots.iter().filter(|sl| !sl.is_forward) {
        let (st, j) = (sl.stage, sl.microbatch);
        if st < s_n - 1 {
            ops.push(P2pOp { stage: st, is_send: false, src: st + 1, dst: st, tag: bwd_channel_tag(j) });
        }
        if st > 0 {
            ops.push(P2pOp { stage: st, is_send: true, src: st, dst: st - 1, tag: bwd_channel_tag(j) });
        }
    }
    ops
}

/// Verify a P2P program: every recv matched by an already-issued send
/// (the sequential executor's requirement), no pending sends after the
/// step, and an acyclic cross-stage wait-for graph (the requirement
/// even under fully parallel stage execution).
pub fn verify_p2p_program(ops: &[P2pOp]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();

    // --- sequential-executor walk: per-channel FIFO ---------------------
    // channel key -> queue of op indices of not-yet-consumed sends
    let mut channels: BTreeMap<(usize, usize, u64), Vec<usize>> = BTreeMap::new();
    // sends already claimed by an order-deadlocked recv, so a single
    // misordered pair yields one finding, not finding + phantom-pending
    let mut claimed: Vec<usize> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        let key = (op.src, op.dst, op.tag);
        if op.is_send {
            if let Some(pos) = claimed.iter().position(|&k| k == i) {
                claimed.swap_remove(pos);
                continue;
            }
            channels.entry(key).or_default().push(i);
            continue;
        }
        let q = channels.entry(key).or_default();
        if !q.is_empty() {
            q.remove(0);
            continue;
        }
        // no send issued yet: is one coming later?
        let later = ops[i + 1..]
            .iter()
            .position(|o| o.is_send && (o.src, o.dst, o.tag) == key)
            .map(|k| i + 1 + k);
        match later {
            Some(k) => {
                claimed.push(k);
                diags.push(diag(
                    CheckId::P2pDeadlock,
                    None,
                    "pipeline",
                    format!(
                        "op {i} {} precedes its matching send (op {k} {}) — the \
                         sequential executor would block forever",
                        op, ops[k]
                    ),
                ));
            }
            None => diags.push(diag(
                CheckId::P2pUnmatched,
                None,
                "pipeline",
                format!("op {i} {} has no matching send anywhere in the program", op),
            )),
        }
    }
    let pending: usize = channels.values().map(|q| q.len()).sum();
    if pending > 0 {
        let example = channels
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|((s, d, t), _)| format!("{s}->{d} tag {t:#x}"))
            .unwrap_or_default();
        diags.push(diag(
            CheckId::P2pUnmatched,
            None,
            "pipeline",
            format!(
                "{pending} send(s) never received (e.g. channel {example}) — \
                 pending_p2p would be {pending} after the step"
            ),
        ));
    }

    // --- wait-for cycle detection (Kahn) --------------------------------
    // Edges: program order within each stage, plus matched send -> recv.
    // Independent of the sequential walk: a cycle deadlocks under ANY
    // interleaving, which is a strictly stronger finding.
    {
        let n = ops.len();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        let mut last_of_stage: BTreeMap<usize, usize> = BTreeMap::new();
        let mut sends: BTreeMap<(usize, usize, u64), Vec<usize>> = BTreeMap::new();
        let mut recv_seq: BTreeMap<(usize, usize, u64), usize> = BTreeMap::new();
        for (i, op) in ops.iter().enumerate() {
            if let Some(&p) = last_of_stage.get(&op.stage) {
                succs[p].push(i);
                indeg[i] += 1;
            }
            last_of_stage.insert(op.stage, i);
            if op.is_send {
                sends.entry((op.src, op.dst, op.tag)).or_default().push(i);
            }
        }
        for (i, op) in ops.iter().enumerate() {
            if op.is_send {
                continue;
            }
            let key = (op.src, op.dst, op.tag);
            let seq = recv_seq.entry(key).or_insert(0);
            if let Some(&s) = sends.get(&key).and_then(|v| v.get(*seq)) {
                succs[s].push(i);
                indeg[i] += 1;
            }
            *seq += 1;
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut done = 0usize;
        while let Some(i) = ready.pop() {
            done += 1;
            for &s in &succs[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if done < n {
            let stuck: Vec<String> = (0..n)
                .filter(|&i| indeg[i] > 0)
                .take(4)
                .map(|i| format!("op {i} {}", ops[i]))
                .collect();
            diags.push(diag(
                CheckId::P2pDeadlock,
                None,
                "pipeline",
                format!(
                    "wait-for cycle across stages: {} op(s) can never become ready \
                     ({}, …) — the program deadlocks under any interleaving",
                    n - done,
                    stuck.join("; ")
                ),
            ));
        }
    }

    diags
}

/// Verify a pipeline grid end to end: lower it to its send/recv program
/// and run the program analysis.
pub fn verify_pipeline(pipe: &PipelineSchedule) -> Vec<Diagnostic> {
    verify_p2p_program(&lower_p2p_program(pipe))
}

// ---------------------------------------------------------------------------
// Plan-level entry points and the lint harness
// ---------------------------------------------------------------------------

/// Lint a materialized [`Plan`]: the schedule checks against the plan's
/// strategy/sharding, the pipeline program analysis, and — when the
/// plan's instance type names a known chip — the watermark cross-check
/// against the AOT report.
pub fn verify_plan(plan: &Plan) -> Result<VerifyReport> {
    let (hbm_capacity, aot_fits) = match chips::by_instance_type(&plan.instance_type) {
        Some(chip) => {
            let aot = aot_compile_check(plan, &chip, None)?;
            (Some(aot.hbm_capacity), Some(aot.fits))
        }
        None => (None, None),
    };
    let ctx = VerifyContext {
        strategy: plan.strategy.clone(),
        shard_axes: shard_axes_from_specs(&plan.sharding, &plan.mesh_axes),
        exact_payloads: false,
        hbm_capacity,
        aot_fits,
    };
    let mut report = verify_schedule(&plan.schedule, Some(&plan.pipeline), &ctx);
    report.diagnostics.extend(verify_pipeline(&plan.pipeline));
    Ok(report)
}

/// The preset/instance pairings the lint harness and CI cover: every
/// mesh rule in [`crate::config::mesh_rules::paper_appendix_a_rules`],
/// on a chip count its pattern anticipates.
pub fn lint_preset_targets() -> Vec<(&'static str, &'static str, usize)> {
    vec![
        ("small", "gpu-H100-32", 256),
        ("small", "gpu-H100-pp-64", 256),
        ("small", "tpu-v5e-256-4", 1024),
        ("tiny", "tpu-v5p-32", 32),
        ("small", "trn2-16", 64),
        ("tiny-moe", "tpu-v5e-moe-512", 512),
    ]
}

/// Lint every mesh-rules preset target.  Returns `(label, report)`
/// rows; an `Err` means materialization itself failed, which is worse
/// than a diagnostic.
pub fn lint_presets() -> Result<Vec<(String, VerifyReport)>> {
    use crate::config::mesh_rules::paper_appendix_a_rules;
    use crate::config::registry::{default_config, trainer_for_preset};
    use crate::config::{replace_config, Value};

    let rules = paper_appendix_a_rules();
    let mut out = Vec::new();
    for (preset, instance, chips_n) in lint_preset_targets() {
        let trainer = if let Some(base) = preset.strip_suffix("-moe") {
            let mut t = trainer_for_preset(base)?;
            replace_config(&mut t, "FeedForward", &|old| {
                default_config("MoE")
                    .expect("MoE is registered")
                    .with("input_dim", old.get("input_dim").expect("ffn input_dim").clone())
                    .with("hidden_dim", old.get("hidden_dim").expect("ffn hidden_dim").clone())
                    .with("num_experts", Value::Int(32))
            });
            t
        } else {
            trainer_for_preset(preset)?
        };
        let plan = super::plan::materialize(&trainer, instance, chips_n, &rules)?;
        let report = verify_plan(&plan)?;
        out.push((format!("{preset}@{instance}x{chips_n}"), report));
    }
    Ok(out)
}

/// Lint the canonical 14-point mesh sweep (the same factorizations
/// `bench_mesh`/`bench_check` gate), with the watermark check wired to
/// each point's estimator verdict.
pub fn lint_sweep() -> Vec<(String, VerifyReport)> {
    let chip = chips::h100();
    let points = super::mesh_sweep::mesh_sweep_points();
    let shard_axes = vec!["fsdp".to_string(), "model".to_string()];
    let mut out = Vec::with_capacity(SWEEP_MESHES.len());
    for (idx, (d, p, f, m, e)) in SWEEP_MESHES.into_iter().enumerate() {
        let shape = if e > 1 { sweep_shape_moe() } else { sweep_shape_dense() };
        let strat = Strategy {
            data: d,
            fsdp: f,
            tensor: m,
            pipeline: p,
            expert: e,
            microbatches: if p > 1 { SWEEP_MICROBATCHES } else { 1 },
        };
        let sched = build_schedule(
            &strat,
            &shape,
            &shard_axes,
            SWEEP_GLOBAL_BATCH,
            SWEEP_SEQ,
            &chip.interconnect,
        );
        let pipe = PipelineSchedule::one_f_one_b(strat.pipeline, strat.microbatches.max(1))
            .expect("swept shapes are feasible");
        let ctx = VerifyContext {
            strategy: strat,
            shard_axes: shard_axes.clone(),
            exact_payloads: false,
            hbm_capacity: Some(chip.hbm_bytes),
            aot_fits: points.get(idx).map(|pt| pt.fits),
        };
        let mut report = verify_schedule(&sched, Some(&pipe), &ctx);
        report.diagnostics.extend(verify_pipeline(&pipe));
        out.push((format!("sweep:{d}x{p}x{f}x{m}x{e}"), report));
    }
    out
}

/// The JSON lint artifact the `verify` binary writes and CI uploads:
/// one row per linted target with its diagnostics spelled out.
pub fn lint_doc(rows: &[(String, VerifyReport)]) -> Json {
    let total: usize = rows.iter().map(|(_, r)| r.diagnostics.len()).sum();
    Json::obj(vec![
        ("tool", Json::str("schedule_verify")),
        ("targets", Json::num(rows.len() as f64)),
        ("diagnostics", Json::num(total as f64)),
        ("clean", Json::Bool(total == 0)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(label, r)| {
                        Json::obj(vec![
                            ("target", Json::str(label.clone())),
                            ("entries", Json::num(r.entries as f64)),
                            ("watermark_bytes", Json::num(r.watermark_bytes)),
                            ("clean", Json::Bool(r.is_clean())),
                            (
                                "diagnostics",
                                Json::Arr(
                                    r.diagnostics
                                        .iter()
                                        .map(|d| {
                                            Json::obj(vec![
                                                ("check", Json::str(d.check.name())),
                                                (
                                                    "entry",
                                                    d.entry
                                                        .map(|i| Json::num(i as f64))
                                                        .unwrap_or(Json::Null),
                                                ),
                                                ("axis", Json::str(d.axis.clone())),
                                                ("message", Json::str(d.message.clone())),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::composer::schedule::ScheduleEntry;
    use crate::perfmodel::comms::hierarchical;

    fn strat() -> Strategy {
        Strategy { data: 2, fsdp: 8, tensor: 2, pipeline: 2, expert: 2, microbatches: 4 }
    }

    fn ctx() -> VerifyContext {
        VerifyContext::for_strategy(&strat())
    }

    fn sched() -> CollectiveSchedule {
        let ic = super::super::schedule::local_interconnect();
        build_schedule(
            &strat(),
            &sweep_shape_moe(),
            &["fsdp".to_string(), "model".to_string()],
            256,
            1024,
            &ic,
        )
    }

    #[test]
    fn emitted_schedules_lint_clean() {
        let s = sched();
        let pipe = PipelineSchedule::one_f_one_b(2, 4).unwrap();
        let r = verify_schedule(&s, Some(&pipe), &ctx());
        assert!(r.is_clean(), "{}", r.render());
        assert!(r.watermark_bytes > 0.0);
        assert!(verify_pipeline(&pipe).is_empty());
    }

    #[test]
    fn overlapping_subgroups_are_caught() {
        let mut s = sched();
        let i = s.entries.iter().position(|e| e.axis == "fsdp").unwrap();
        s.entries[i].count += 1; // group*count now exceeds the grid
        let r = verify_schedule(&s, None, &ctx());
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        let d = &r.diagnostics[0];
        assert_eq!(d.check, CheckId::SubgroupTiling);
        assert_eq!(d.entry, Some(i));
        assert!(d.message.contains(&format!("entry {i}")) && d.message.contains("fsdp"));
    }

    #[test]
    fn unknown_axis_is_caught() {
        let mut s = sched();
        s.entries[0].axis = "bogus".into();
        let r = verify_schedule(&s, None, &ctx());
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        assert_eq!(r.diagnostics[0].check, CheckId::SubgroupTiling);
        assert!(r.diagnostics[0].message.contains("bogus"));
    }

    #[test]
    fn phase_inversion_is_caught() {
        let mut s = sched();
        let i = s
            .entries
            .iter()
            .position(|e| e.collective == Collective::AllGather)
            .unwrap();
        s.entries[i].phase = SchedulePhase::Update;
        // re-sort the way the composer would, so only the per-entry
        // legality (not the monotonicity) can fire
        let s = CollectiveSchedule::new(s.entries);
        let i = s
            .entries
            .iter()
            .position(|e| e.collective == Collective::AllGather)
            .unwrap();
        let r = verify_schedule(&s, None, &ctx());
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        let d = &r.diagnostics[0];
        assert_eq!(d.check, CheckId::PhaseOrder);
        assert_eq!(d.entry, Some(i));
    }

    #[test]
    fn non_monotone_issue_order_is_caught() {
        let s = sched();
        let mut entries = s.entries;
        entries.reverse(); // Update now precedes Gather
        let s = CollectiveSchedule { entries };
        let r = verify_schedule(&s, None, &ctx());
        assert!(
            r.diagnostics.iter().any(|d| d.check == CheckId::PhaseOrder
                && d.message.contains("not phase-monotone")),
            "{}",
            r.render()
        );
    }

    #[test]
    fn alltoall_bucket_leak_is_caught() {
        let mut s = sched();
        let i = s
            .entries
            .iter()
            .position(|e| e.tensor == "moe-combine")
            .unwrap();
        s.entries[i].bytes += 64.0;
        let r = verify_schedule(&s, None, &ctx());
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        let d = &r.diagnostics[0];
        assert_eq!(d.check, CheckId::PayloadConservation);
        assert!(d.message.contains("bucket totals leak"), "{}", d.message);
        assert!(d.message.contains("expert"));
    }

    #[test]
    fn gather_scatter_asymmetry_is_caught() {
        let mut s = sched();
        let i = s
            .entries
            .iter()
            .position(|e| e.collective == Collective::ReduceScatter)
            .unwrap();
        s.entries[i].bytes *= 2.0;
        let r = verify_schedule(&s, None, &ctx());
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        assert!(r.diagnostics[0].message.contains("conserve bytes"));
    }

    #[test]
    fn divisibility_needs_exact_payloads() {
        let ic = super::super::schedule::local_interconnect();
        let entry = ScheduleEntry {
            phase: SchedulePhase::Gather,
            collective: Collective::AllGather,
            axis: "fsdp".into(),
            group: 8,
            count: 8,
            tensor: "w0".into(),
            bytes: 4.0 * 8.0 * 3.0 + 4.0, // not a multiple of 4*group
            cost_s: hierarchical(Collective::AllGather, 100.0, 8, &ic),
            rounds: 1,
            overlappable: true,
        };
        let strat = Strategy { data: 8, fsdp: 8, tensor: 1, pipeline: 1, expert: 1, microbatches: 1 };
        let mut c = VerifyContext::for_strategy(&strat);
        let s = CollectiveSchedule { entries: vec![entry] };
        assert!(verify_schedule(&s, None, &c).is_clean());
        c.exact_payloads = true;
        let r = verify_schedule(&s, None, &c);
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        assert_eq!(r.diagnostics[0].check, CheckId::PayloadConservation);
        assert!(r.diagnostics[0].message.contains("equal split"));
    }

    #[test]
    fn watermark_over_hbm_is_caught() {
        let s = sched();
        let mut c = ctx();
        c.hbm_capacity = Some(1.0); // one byte of HBM
        c.aot_fits = Some(true);
        let r = verify_schedule(&s, None, &c);
        assert_eq!(r.diagnostics.len(), 1, "{}", r.render());
        let d = &r.diagnostics[0];
        assert_eq!(d.check, CheckId::Watermark);
        assert!(d.message.contains("disagree"));
        // when the AOT check already rejected the plan the reports agree
        c.aot_fits = Some(false);
        assert!(verify_schedule(&s, None, &c).is_clean());
    }

    #[test]
    fn p2p_program_matches_and_drains() {
        for (s_n, m) in [(2usize, 4usize), (4, 8), (4, 4), (8, 16)] {
            for pipe in [
                PipelineSchedule::one_f_one_b(s_n, m).unwrap(),
                PipelineSchedule::gpipe(s_n, m).unwrap(),
            ] {
                let diags = verify_pipeline(&pipe);
                assert!(
                    diags.is_empty(),
                    "{s_n}x{m} {:?}: {:?}",
                    pipe.kind,
                    diags
                );
                // 2*(S-1)*m sends and as many recvs per direction pair
                let ops = lower_p2p_program(&pipe);
                assert_eq!(ops.len(), 4 * (s_n - 1) * m);
            }
        }
    }

    #[test]
    fn unmatched_send_is_caught() {
        let pipe = PipelineSchedule::gpipe(2, 2).unwrap();
        let mut ops = lower_p2p_program(&pipe);
        // drop a recv: its send is now never consumed
        let i = ops.iter().position(|o| !o.is_send).unwrap();
        ops.remove(i);
        let diags = verify_p2p_program(&ops);
        assert!(
            diags
                .iter()
                .any(|d| d.check == CheckId::P2pUnmatched && d.message.contains("pending_p2p")),
            "{diags:?}"
        );
    }

    #[test]
    fn recv_without_any_send_is_caught() {
        let ops = vec![P2pOp { stage: 1, is_send: false, src: 0, dst: 1, tag: 0 }];
        let diags = verify_p2p_program(&ops);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].check, CheckId::P2pUnmatched);
        assert!(diags[0].message.contains("no matching send"));
    }

    #[test]
    fn order_deadlock_is_caught() {
        // recv issued before its matching send in executor order
        let ops = vec![
            P2pOp { stage: 1, is_send: false, src: 0, dst: 1, tag: 7 },
            P2pOp { stage: 0, is_send: true, src: 0, dst: 1, tag: 7 },
        ];
        let diags = verify_p2p_program(&ops);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].check, CheckId::P2pDeadlock);
        assert!(diags[0].message.contains("block forever"));
    }

    #[test]
    fn wait_for_cycle_is_caught() {
        // two stages, each recv-then-send toward the other on distinct
        // channels: a classic head-of-line cycle no interleaving solves
        let ops = vec![
            P2pOp { stage: 0, is_send: false, src: 1, dst: 0, tag: 1 },
            P2pOp { stage: 0, is_send: true, src: 0, dst: 1, tag: 0 },
            P2pOp { stage: 1, is_send: false, src: 0, dst: 1, tag: 0 },
            P2pOp { stage: 1, is_send: true, src: 1, dst: 0, tag: 1 },
        ];
        let diags = verify_p2p_program(&ops);
        assert!(
            diags.iter().any(|d| d.check == CheckId::P2pDeadlock
                && d.message.contains("wait-for cycle")),
            "{diags:?}"
        );
    }

    #[test]
    fn presets_and_sweep_lint_clean() {
        for (label, report) in lint_presets().unwrap() {
            assert!(report.is_clean(), "{label}: {}", report.render());
        }
        let rows = lint_sweep();
        assert_eq!(rows.len(), SWEEP_MESHES.len());
        for (label, report) in &rows {
            assert!(report.is_clean(), "{label}: {}", report.render());
        }
        let doc = lint_doc(&rows);
        assert_eq!(doc.get("clean").and_then(|v| v.as_bool()), Some(true));
    }
}
