//! Sharding annotations (§4.2 "Config-based parallelism").
//!
//! Layers carry `param_partition_spec` fields; the composer collects them
//! into a flat annotation table the runtime/perfmodel consume.  The bias
//! spec is *inferred* from the weight spec (the paper calls this out:
//! "AXLearn's Linear layer implementation automatically infers the bias
//! sharding from the sharding of the model weights, which minimizes
//! communication costs").
//!
//! See `docs/sharding.md` for the end-to-end story: mesh rules pick the
//! mesh shape, these specs say which axes shard which tensors, and
//! [`super::schedule`] / [`crate::distributed::mesh`] turn the result
//! into explicit collectives.

use crate::config::{visit, ConfigNode, Value};

/// One parameter's sharding annotation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardingSpec {
    /// Config path of the owning layer.
    pub layer_path: String,
    /// Parameter name within the layer ("weight", "bias").
    pub param: String,
    /// Mesh axis per tensor dim; "replicated" marks an unsharded dim.
    pub axes: Vec<String>,
}

/// Resolve a partition spec against the mesh axis names: axes not present
/// in the mesh degrade to replication (XLA semantics: missing axis =>
/// replicated), preserving validity across targets.
///
/// ```
/// use axlearn::composer::resolve_partition_spec;
///
/// // A ("fsdp", "model") weight on a data×fsdp mesh: the model axis
/// // does not exist on this target, so that dim replicates.
/// let spec = vec!["fsdp".to_string(), "model".to_string()];
/// let mesh = vec!["data".to_string(), "fsdp".to_string()];
/// assert_eq!(
///     resolve_partition_spec(&spec, &mesh),
///     vec!["fsdp".to_string(), "replicated".to_string()]
/// );
///
/// // Resolution is idempotent: re-resolving changes nothing.
/// let once = resolve_partition_spec(&spec, &mesh);
/// assert_eq!(resolve_partition_spec(&once, &mesh), once);
/// ```
pub fn resolve_partition_spec(spec: &[String], mesh_axes: &[String]) -> Vec<String> {
    spec.iter()
        .map(|a| {
            if mesh_axes.iter().any(|m| m == a) {
                a.clone()
            } else {
                "replicated".to_string()
            }
        })
        .collect()
}

/// Infer the bias spec from the weight spec: the bias is sharded like the
/// weight's *output* dim (last axis), everything else replicated.
///
/// ```
/// use axlearn::composer::infer_bias_spec;
///
/// let weight = vec!["fsdp".to_string(), "model".to_string()];
/// assert_eq!(infer_bias_spec(&weight), vec!["model".to_string()]);
/// ```
pub fn infer_bias_spec(weight_axes: &[String]) -> Vec<String> {
    match weight_axes.last() {
        Some(last) => vec![last.clone()],
        None => vec![],
    }
}

/// The mesh axes a parameter set actually shards over: the union, across
/// all specs, of resolved axes that name a real mesh axis.  Mesh axes
/// *not* in this set replicate parameters (extra data parallelism) —
/// [`super::schedule::build_schedule`] and
/// [`crate::distributed::mesh::MeshTrainer`] both key off this.
pub fn shard_axes_from_specs(specs: &[ShardingSpec], mesh_axes: &[String]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for spec in specs {
        for axis in resolve_partition_spec(&spec.axes, mesh_axes) {
            if axis != "replicated" && !out.contains(&axis) {
                out.push(axis);
            }
        }
    }
    out.sort();
    out
}

/// Walk the config tree collecting every `param_partition_spec`.
pub fn collect_sharding(trainer: &ConfigNode) -> Vec<ShardingSpec> {
    let mut out = Vec::new();
    visit(trainer, &mut |path, node| {
        if let Ok(Value::StrList(axes)) = node.get("param_partition_spec") {
            out.push(ShardingSpec {
                layer_path: path.to_string(),
                param: "weight".into(),
                axes: axes.clone(),
            });
            if matches!(node.get("use_bias"), Ok(Value::Bool(true))) {
                out.push(ShardingSpec {
                    layer_path: path.to_string(),
                    param: "bias".into(),
                    axes: infer_bias_spec(axes),
                });
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registry::trainer_for_preset;

    #[test]
    fn resolve_degrades_missing_axes_to_replicated() {
        let spec = vec!["fsdp".to_string(), "model".to_string()];
        let mesh = vec!["data".to_string(), "fsdp".to_string()];
        assert_eq!(
            resolve_partition_spec(&spec, &mesh),
            vec!["fsdp".to_string(), "replicated".to_string()]
        );
    }

    #[test]
    fn bias_inherits_output_axis() {
        // ("fsdp", "model") weights => ("model",) bias — the paper's example.
        let axes = vec!["fsdp".to_string(), "model".to_string()];
        assert_eq!(infer_bias_spec(&axes), vec!["model".to_string()]);
    }

    #[test]
    fn collect_finds_every_linear() {
        let t = trainer_for_preset("small").unwrap();
        let specs = collect_sharding(&t);
        // qkv_proj + out_proj templates + ffn linear template
        assert!(specs.len() >= 3, "{specs:?}");
        for s in &specs {
            assert_eq!(s.axes, vec!["fsdp".to_string(), "model".to_string()]);
        }
    }

    #[test]
    fn bias_specs_only_when_bias_enabled() {
        let t = trainer_for_preset("small").unwrap();
        let specs = collect_sharding(&t);
        assert!(specs.iter().all(|s| s.param == "weight"));
    }

    #[test]
    fn shard_axes_are_the_resolved_union() {
        let t = trainer_for_preset("small").unwrap();
        let specs = collect_sharding(&t);
        let full = vec!["data".to_string(), "fsdp".to_string(), "model".to_string()];
        assert_eq!(shard_axes_from_specs(&specs, &full), vec!["fsdp", "model"]);
        // on a data×fsdp mesh the model dim replicates away
        let no_tp = vec!["data".to_string(), "fsdp".to_string()];
        assert_eq!(shard_axes_from_specs(&specs, &no_tp), vec!["fsdp"]);
        // an empty mesh shards nothing
        assert!(shard_axes_from_specs(&specs, &[]).is_empty());
    }
}
