//! AOT compile-check (§4.2): "analyze the memory and FLOPS utilization of
//! a training program without executing a single line of the program,
//! including catching errors like OOMs that would otherwise result in
//! wasted resources".
//!
//! Given a materialized [`Plan`] and a target chip, report the per-chip
//! memory picture and predicted utilization — from a single (CPU-only)
//! host, before any accelerator is provisioned.  Because the same plan
//! drives the simulated run, "a program that AOT-compiles will run".

use anyhow::Result;

use crate::perfmodel::chips::ChipSpec;
use crate::perfmodel::estimator::{estimate_step, StepSpec, SystemProfile};

use super::plan::Plan;

/// The AOT analysis report.
#[derive(Clone, Debug)]
pub struct AotReport {
    /// Whether the plan fits in the target chip's HBM.
    pub fits: bool,
    /// Predicted per-chip HBM footprint (NaN when the plan OOMs).
    pub hbm_used_bytes: f64,
    /// The target chip's HBM capacity.
    pub hbm_capacity: f64,
    /// Predicted step time (NaN when the plan OOMs).
    pub predicted_step_time_s: f64,
    /// Predicted model FLOPS utilization (0 when the plan OOMs).
    pub predicted_mfu: f64,
    /// The remat policy the estimator settled on ("-" when it OOMs).
    pub remat_policy: String,
    /// Model FLOPs of one training step (defined even on OOM).
    pub flops_per_step: f64,
    /// Human-readable outcome ("OK" or the OOM message).
    pub message: String,
}

/// Run the AOT check for a plan against a chip, under a system profile
/// (defaults to AXLearn's own).
pub fn aot_compile_check(plan: &Plan, chip: &ChipSpec, profile: Option<&SystemProfile>) -> Result<AotReport> {
    let default_profile = SystemProfile::axlearn();
    let profile = profile.unwrap_or(&default_profile);
    let spec = StepSpec {
        shape: plan.shape.clone(),
        strategy: plan.strategy.clone(),
        global_batch: plan.global_batch.max(plan.strategy.total_chips()),
        seq_len: plan.seq_len,
        quantization: plan.quantization.clone(),
        remat_policy: if plan.remat_policy == "none" {
            "auto".into()
        } else {
            plan.remat_policy.clone()
        },
    };
    let flops = (spec.global_batch * spec.seq_len) as f64
        * plan.shape.train_flops_per_token(plan.seq_len as u64);
    match estimate_step(&spec, chip, profile) {
        Ok(e) => Ok(AotReport {
            fits: true,
            hbm_used_bytes: e.hbm_used_bytes,
            hbm_capacity: e.hbm_capacity,
            predicted_step_time_s: e.step_time_s,
            predicted_mfu: e.mfu,
            remat_policy: e.remat_policy,
            flops_per_step: flops,
            message: "OK".into(),
        }),
        Err(err) => {
            let msg = format!("{err:#}");
            if msg.contains("OOM") {
                Ok(AotReport {
                    fits: false,
                    hbm_used_bytes: f64::NAN,
                    hbm_capacity: chip.hbm_bytes,
                    predicted_step_time_s: f64::NAN,
                    predicted_mfu: 0.0,
                    remat_policy: "-".into(),
                    flops_per_step: flops,
                    message: msg,
                })
            } else {
                Err(err)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::composer::plan::materialize;
    use crate::config::mesh_rules::paper_appendix_a_rules;
    use crate::config::registry::trainer_for_preset;
    use crate::config::Value;
    use crate::perfmodel::chips;

    #[test]
    fn tiny_fits_everywhere() {
        let t = trainer_for_preset("tiny").unwrap();
        let plan = materialize(&t, "tpu-v5p-32", 32, &paper_appendix_a_rules()).unwrap();
        let r = aot_compile_check(&plan, &chips::tpu_v5p(), None).unwrap();
        assert!(r.fits, "{}", r.message);
        assert!(r.predicted_mfu > 0.0);
        assert!(r.hbm_used_bytes < r.hbm_capacity);
    }

    #[test]
    fn oom_caught_without_running() {
        // a deliberately absurd plan: base100m replicated on one v5e chip
        // with a big batch and remat disabled
        let mut t = trainer_for_preset("base100m").unwrap();
        t.at_path_mut("input").unwrap().set("batch_size", Value::Int(4096)).unwrap();
        t.at_path_mut("input").unwrap().set("seq_len", Value::Int(8192)).unwrap();
        let plan = materialize(&t, "cpu-local", 1, &paper_appendix_a_rules()).unwrap();
        let mut no_remat = crate::perfmodel::SystemProfile::axlearn();
        no_remat.allowed_remat = vec!["none"];
        let r = aot_compile_check(&plan, &chips::tpu_v5e(), Some(&no_remat)).unwrap();
        assert!(!r.fits);
        assert!(r.message.contains("OOM"));
    }

    #[test]
    fn same_codepath_for_aot_and_run() {
        // The §4.2 guarantee: the AOT report's step estimate equals the
        // estimator's answer for the same plan (it IS the same call).
        let t = trainer_for_preset("small").unwrap();
        let plan = materialize(&t, "gpu-H100-32", 256, &paper_appendix_a_rules()).unwrap();
        let r1 = aot_compile_check(&plan, &chips::h100(), None).unwrap();
        let r2 = aot_compile_check(&plan, &chips::h100(), None).unwrap();
        assert_eq!(r1.predicted_step_time_s, r2.predicted_step_time_s);
    }
}
