//! The generic measurement interface + goodput accounting (§5):
//! "AXLearn supports a generic measurement interface that can be used to
//! record arbitrary events such as the start of training or the start of
//! a step.  These events can be used to measure end-to-end inefficiencies
//! ... captured via metrics like overall job goodput."
//!
//! Goodput = time spent making *durable* forward progress / total
//! wall-clock time.  Work after the last checkpoint that is lost to a
//! failure counts as badput, as do provisioning, compilation, restarts,
//! and checkpoint-restore time.

use std::collections::BTreeMap;

/// Event kinds on the measurement interface.  Times are in seconds on a
/// caller-supplied clock (the cluster simulator uses virtual time; the
/// real trainer uses `Instant`-derived seconds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    JobStart,
    ProvisioningDone,
    CompilationDone,
    StepDone,
    CheckpointDurable,
    FailureDetected,
    RestartBegin,
    RestartDone,
    JobEnd,
}

#[derive(Clone, Debug)]
pub struct Event {
    pub kind: EventKind,
    pub t: f64,
    /// Step number for StepDone/CheckpointDurable.
    pub step: u64,
}

/// Records events; computes goodput and a time breakdown.
#[derive(Default)]
pub struct GoodputTracker {
    events: Vec<Event>,
}

impl GoodputTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, kind: EventKind, t: f64, step: u64) {
        self.events.push(Event { kind, t, step });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Total wall time between JobStart and JobEnd (or the last event).
    pub fn wall_time(&self) -> f64 {
        let start = self
            .events
            .iter()
            .find(|e| e.kind == EventKind::JobStart)
            .map(|e| e.t)
            .unwrap_or(0.0);
        let end = self
            .events
            .iter()
            .rev()
            .find(|e| e.kind == EventKind::JobEnd)
            .map(|e| e.t)
            .or_else(|| self.events.last().map(|e| e.t))
            .unwrap_or(start);
        end - start
    }

    /// Step-time spent on steps whose progress survived (i.e. steps at or
    /// below a checkpoint that became durable before the next failure).
    pub fn goodput(&self) -> f64 {
        let wall = self.wall_time();
        if wall <= 0.0 {
            return 0.0;
        }
        // Walk events; accumulate step intervals, crediting them only up
        // to the last durable checkpoint when a failure intervenes.
        let mut credited = 0.0;
        let mut pending: Vec<(u64, f64)> = Vec::new(); // (step, duration)
        let mut last_t: Option<f64> = None;
        let mut durable_step = 0u64;
        for e in &self.events {
            match e.kind {
                EventKind::StepDone => {
                    if let Some(prev) = last_t {
                        pending.push((e.step, e.t - prev));
                    }
                    last_t = Some(e.t);
                }
                EventKind::CheckpointDurable => {
                    durable_step = durable_step.max(e.step);
                    // credit all pending steps <= durable step
                    let (keep, credit): (Vec<_>, Vec<_>) =
                        pending.drain(..).partition(|(s, _)| *s > durable_step);
                    credited += credit.iter().map(|(_, d)| d).sum::<f64>();
                    pending = keep;
                }
                EventKind::FailureDetected => {
                    // uncheckpointed progress is lost
                    pending.clear();
                    last_t = None;
                }
                EventKind::JobEnd => {
                    // surviving uncheckpointed work at job end still counts
                    credited += pending.drain(..).map(|(_, d)| d).sum::<f64>();
                }
                EventKind::RestartDone => {
                    last_t = Some(e.t);
                }
                _ => {}
            }
        }
        credited += pending.iter().map(|(_, d)| d).sum::<f64>();
        (credited / wall).clamp(0.0, 1.0)
    }

    /// Seconds per phase (provisioning, compilation, restarts, …).
    pub fn breakdown(&self) -> BTreeMap<&'static str, f64> {
        let mut out = BTreeMap::new();
        let mut job_start = None;
        let mut prov_done = None;
        let mut restart_begin = None;
        let mut restart_total = 0.0;
        for e in &self.events {
            match e.kind {
                EventKind::JobStart => job_start = Some(e.t),
                EventKind::ProvisioningDone => prov_done = Some(e.t),
                EventKind::CompilationDone => {
                    if let Some(p) = prov_done {
                        out.insert("compilation", e.t - p);
                    }
                }
                EventKind::RestartBegin => restart_begin = Some(e.t),
                EventKind::RestartDone => {
                    if let Some(b) = restart_begin.take() {
                        restart_total += e.t - b;
                    }
                }
                _ => {}
            }
        }
        if let (Some(j), Some(p)) = (job_start, prov_done) {
            out.insert("provisioning", p - j);
        }
        out.insert("restarts", restart_total);
        out.insert("wall", self.wall_time());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_run_goodput_near_one() {
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::RestartDone, 0.0, 0); // marks step clock start
        for s in 1..=10 {
            g.record(EventKind::StepDone, s as f64, s);
        }
        g.record(EventKind::CheckpointDurable, 10.0, 10);
        g.record(EventKind::JobEnd, 10.0, 10);
        assert!(g.goodput() > 0.99, "{}", g.goodput());
    }

    #[test]
    fn failure_without_checkpoint_is_badput() {
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::RestartDone, 0.0, 0);
        for s in 1..=5 {
            g.record(EventKind::StepDone, s as f64, s);
        }
        g.record(EventKind::FailureDetected, 5.0, 5);
        g.record(EventKind::RestartDone, 8.0, 0);
        for s in 1..=2 {
            g.record(EventKind::StepDone, 8.0 + s as f64, s);
        }
        g.record(EventKind::CheckpointDurable, 10.0, 2);
        g.record(EventKind::JobEnd, 10.0, 2);
        // only the 2 post-restart steps count out of 10s wall
        assert!((g.goodput() - 0.2).abs() < 0.05, "{}", g.goodput());
    }

    #[test]
    fn checkpoint_preserves_credit_across_failure() {
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::RestartDone, 0.0, 0);
        for s in 1..=4 {
            g.record(EventKind::StepDone, s as f64, s);
        }
        g.record(EventKind::CheckpointDurable, 4.0, 4);
        g.record(EventKind::StepDone, 5.0, 5); // will be lost
        g.record(EventKind::FailureDetected, 5.5, 5);
        g.record(EventKind::JobEnd, 6.0, 4);
        let gp = g.goodput();
        assert!((gp - 4.0 / 6.0).abs() < 0.05, "{gp}");
    }

    #[test]
    fn durable_event_after_failure_does_not_credit_lost_steps() {
        // an async checkpoint that reaches durability only after the
        // failure cannot resurrect the steps the failure already lost
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::RestartDone, 0.0, 0);
        for s in 1..=4 {
            g.record(EventKind::StepDone, s as f64, s);
        }
        g.record(EventKind::FailureDetected, 4.5, 4);
        // the in-flight save of step 4 lands mid-restart
        g.record(EventKind::CheckpointDurable, 5.0, 4);
        g.record(EventKind::RestartDone, 6.0, 4);
        g.record(EventKind::StepDone, 7.0, 5);
        g.record(EventKind::StepDone, 8.0, 6);
        g.record(EventKind::CheckpointDurable, 8.0, 6);
        g.record(EventKind::JobEnd, 8.0, 6);
        // only the two post-restart steps are credited: 2s of 8s wall
        let gp = g.goodput();
        assert!((gp - 0.25).abs() < 0.01, "{gp}");
    }

    #[test]
    fn goodput_without_job_end_uses_last_event() {
        // a tracker snapshotted mid-run (no JobEnd yet, e.g. a crash
        // before the books close) still reports a sane goodput
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::RestartDone, 0.0, 0);
        for s in 1..=5 {
            g.record(EventKind::StepDone, s as f64, s);
        }
        assert_eq!(g.wall_time(), 5.0);
        // surviving uncheckpointed work still counts as credited progress
        assert!(g.goodput() > 0.99, "{}", g.goodput());
    }

    #[test]
    fn breakdown_accounts_phases() {
        let mut g = GoodputTracker::new();
        g.record(EventKind::JobStart, 0.0, 0);
        g.record(EventKind::ProvisioningDone, 3.0, 0);
        g.record(EventKind::CompilationDone, 5.0, 0);
        g.record(EventKind::RestartBegin, 10.0, 0);
        g.record(EventKind::RestartDone, 12.0, 0);
        g.record(EventKind::JobEnd, 20.0, 0);
        let b = g.breakdown();
        assert_eq!(b["provisioning"], 3.0);
        assert_eq!(b["compilation"], 2.0);
        assert_eq!(b["restarts"], 2.0);
        assert_eq!(b["wall"], 20.0);
    }
}
