//! In-process span profiler (§5 "Monitoring and profiling").
//!
//! The paper integrates JAX's profiler and lets users "attach" to
//! in-flight programs.  The Rust-side equivalent: a lightweight
//! hierarchical span profiler the trainer and serving engine record
//! phase timings into, with an on-demand report (the "attach" analogue —
//! no restart needed, `report()` any time).

use std::collections::BTreeMap;
use std::time::Instant;

/// Aggregated statistics for one span label.
#[derive(Clone, Debug, Default)]
pub struct SpanStats {
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

/// A hierarchical span profiler.  Labels are `/`-joined paths mirroring
/// the InvocationContext hierarchy (e.g. `train/step/execute`).
#[derive(Default)]
pub struct Profiler {
    spans: BTreeMap<String, SpanStats>,
    stack: Vec<(String, Instant)>,
    enabled: bool,
}

impl Profiler {
    pub fn new(enabled: bool) -> Self {
        Profiler {
            enabled,
            ..Default::default()
        }
    }

    /// Start a span; must be matched by `end()` (LIFO).
    pub fn begin(&mut self, label: &str) {
        if !self.enabled {
            return;
        }
        let path = match self.stack.last() {
            Some((parent, _)) => format!("{parent}/{label}"),
            None => label.to_string(),
        };
        self.stack.push((path, Instant::now()));
    }

    /// End the innermost span.
    pub fn end(&mut self) {
        if !self.enabled {
            return;
        }
        if let Some((path, t0)) = self.stack.pop() {
            let dt = t0.elapsed().as_secs_f64();
            let s = self.spans.entry(path).or_default();
            s.count += 1;
            s.total_s += dt;
            s.max_s = s.max_s.max(dt);
        }
    }

    /// Time a closure under a span.
    pub fn scope<T, F: FnOnce() -> T>(&mut self, label: &str, f: F) -> T {
        self.begin(label);
        let out = f();
        self.end();
        out
    }

    pub fn stats(&self, label: &str) -> Option<&SpanStats> {
        self.spans.get(label)
    }

    /// Fraction of a parent span spent in one of its children.
    pub fn fraction(&self, parent: &str, child_path: &str) -> Option<f64> {
        let p = self.spans.get(parent)?;
        let c = self.spans.get(child_path)?;
        if p.total_s > 0.0 {
            Some(c.total_s / p.total_s)
        } else {
            None
        }
    }

    /// Human-readable report, sorted by total time (the on-demand
    /// "attach" output).
    pub fn report(&self) -> String {
        let mut rows: Vec<(&String, &SpanStats)> = self.spans.iter().collect();
        rows.sort_by(|a, b| b.1.total_s.partial_cmp(&a.1.total_s).unwrap());
        let mut out = format!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}\n",
            "span", "count", "total", "mean", "max"
        );
        for (path, s) in rows {
            out.push_str(&format!(
                "{:<44} {:>8} {:>11.3}s {:>11.4}s {:>11.4}s\n",
                path,
                s.count,
                s.total_s,
                s.total_s / s.count.max(1) as f64,
                s.max_s
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_is_free_and_silent() {
        let mut p = Profiler::new(false);
        p.scope("x", || 1 + 1);
        assert!(p.stats("x").is_none());
        assert!(p.report().lines().count() <= 1);
    }

    #[test]
    fn spans_nest_into_paths() {
        let mut p = Profiler::new(true);
        p.begin("train");
        p.begin("step");
        std::thread::sleep(std::time::Duration::from_millis(2));
        p.end();
        p.end();
        assert_eq!(p.stats("train").unwrap().count, 1);
        assert_eq!(p.stats("train/step").unwrap().count, 1);
        assert!(p.stats("train/step").unwrap().total_s > 0.0015);
        assert!(p.stats("train").unwrap().total_s >= p.stats("train/step").unwrap().total_s);
    }

    #[test]
    fn scope_counts_accumulate() {
        let mut p = Profiler::new(true);
        for _ in 0..5 {
            p.scope("io", || {});
        }
        assert_eq!(p.stats("io").unwrap().count, 5);
    }

    #[test]
    fn fraction_of_parent() {
        let mut p = Profiler::new(true);
        p.scope("outer", || {
            // fake inner timing via direct span manipulation
        });
        p.begin("outer");
        p.begin("inner");
        std::thread::sleep(std::time::Duration::from_millis(2));
        p.end();
        p.end();
        let f = p.fraction("outer", "outer/inner").unwrap();
        assert!(f > 0.0 && f <= 1.0, "{f}");
    }

    #[test]
    fn report_sorted_by_total() {
        let mut p = Profiler::new(true);
        p.begin("slow");
        std::thread::sleep(std::time::Duration::from_millis(3));
        p.end();
        p.scope("fast", || {});
        let report = p.report();
        let slow_pos = report.find("slow").unwrap();
        let fast_pos = report.find("fast").unwrap();
        assert!(slow_pos < fast_pos, "{report}");
    }
}
