//! Monitoring, failure detection, and goodput accounting (§5).

pub mod goodput;
pub mod profiler;
pub mod sdc;
pub mod watchdog;

pub use goodput::{EventKind, GoodputTracker};
pub use profiler::Profiler;
pub use sdc::{SdcChecker, SdcReport};
pub use watchdog::{Watchdog, WatchdogAction, WatchdogOptions};
