//! Silent-data-corruption (SDC) checks (§5): "repeating a single
//! communication multiple times to check for interconnect problems, and
//! alternating kernel execution on devices with multiple cores to check
//! result consistency".
//!
//! The checker is generic over an executor function so it runs both
//! against the real PJRT session (re-executing a step on identical inputs
//! must be bit-identical on a healthy host) and against the cluster
//! simulator (where failure injection flips bits to validate detection).

use anyhow::Result;

/// Outcome of one SDC sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct SdcReport {
    pub repeats: usize,
    pub mismatches: usize,
    /// Index of first mismatching repeat, if any.
    pub first_bad: Option<usize>,
}

impl SdcReport {
    pub fn healthy(&self) -> bool {
        self.mismatches == 0
    }
}

/// Configuration for the checker.
pub struct SdcChecker {
    pub repeats: usize,
    /// Compare across "cores" by asking the executor to run on alternate
    /// core ids (0/1); executors that have one core ignore the id.
    pub alternate_cores: bool,
    pub sweeps_run: u64,
    pub corruption_detected: u64,
}

impl SdcChecker {
    pub fn new(repeats: usize, alternate_cores: bool) -> Self {
        SdcChecker {
            repeats: repeats.max(2),
            alternate_cores,
            sweeps_run: 0,
            corruption_detected: 0,
        }
    }

    /// Run one sweep: `exec(core_id)` must be a deterministic computation
    /// (e.g. re-running a collective, or a step on frozen inputs).
    /// Results are compared bit-exactly.
    pub fn sweep<F>(&mut self, mut exec: F) -> Result<SdcReport>
    where
        F: FnMut(usize) -> Result<Vec<f32>>,
    {
        self.sweeps_run += 1;
        let reference = exec(0)?;
        let mut mismatches = 0;
        let mut first_bad = None;
        for i in 1..self.repeats {
            let core = if self.alternate_cores { i % 2 } else { 0 };
            let out = exec(core)?;
            let same = out.len() == reference.len()
                && out
                    .iter()
                    .zip(&reference)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same {
                mismatches += 1;
                first_bad.get_or_insert(i);
            }
        }
        if mismatches > 0 {
            self.corruption_detected += 1;
        }
        Ok(SdcReport {
            repeats: self.repeats,
            mismatches,
            first_bad,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn healthy_executor_passes() {
        let mut c = SdcChecker::new(4, true);
        let r = c.sweep(|_| Ok(vec![1.0, 2.0, 3.0])).unwrap();
        assert!(r.healthy());
        assert_eq!(c.corruption_detected, 0);
    }

    #[test]
    fn flipped_bit_detected() {
        let mut c = SdcChecker::new(3, false);
        let mut call = 0;
        let r = c
            .sweep(|_| {
                call += 1;
                let mut v = vec![1.0f32, 2.0, 3.0];
                if call == 3 {
                    // single-bit flip in one repeat — the classic SDC
                    v[1] = f32::from_bits(v[1].to_bits() ^ 1);
                }
                Ok(v)
            })
            .unwrap();
        assert!(!r.healthy());
        assert_eq!(r.first_bad, Some(2));
        assert_eq!(c.corruption_detected, 1);
    }

    #[test]
    fn core_dependent_fault_found_by_alternation() {
        // a fault on core 1 only: alternate_cores finds it, single-core miss
        let faulty = |core: usize| -> Result<Vec<f32>> {
            Ok(if core == 1 { vec![9.0] } else { vec![1.0] })
        };
        let mut with = SdcChecker::new(4, true);
        assert!(!with.sweep(faulty).unwrap().healthy());
        let mut without = SdcChecker::new(4, false);
        assert!(without.sweep(faulty).unwrap().healthy());
    }

    #[test]
    fn detection_probability_scales_with_repeats() {
        // property: an intermittent fault with p=0.5 per call is detected
        // far more often with 6 repeats than with 2.
        let mut detect = |repeats: usize, seed: u64| -> bool {
            let mut rng = Rng::new(seed);
            let mut c = SdcChecker::new(repeats, false);
            !c.sweep(|_| {
                Ok(vec![if rng.gen_bool(0.5) { 1.0 } else { 2.0 }])
            })
            .unwrap()
            .healthy()
        };
        let trials = 200;
        let hits2 = (0..trials).filter(|&s| detect(2, s)).count();
        let hits6 = (0..trials).filter(|&s| detect(6, 10_000 + s)).count();
        assert!(hits6 > hits2, "{hits6} vs {hits2}");
    }

    #[test]
    fn nan_differs_from_number() {
        let mut c = SdcChecker::new(2, false);
        let mut call = 0;
        let r = c
            .sweep(|_| {
                call += 1;
                Ok(vec![if call == 2 { f32::NAN } else { 1.0 }])
            })
            .unwrap();
        assert!(!r.healthy());
    }
}
