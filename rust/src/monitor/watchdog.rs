//! The watchdog (§5): monitors step time and hardware utilization; on
//! anomaly, forces a restart, alerts an on-call, or dumps stack traces.
//! ("a large fleet is expected to encounter hardware failures several
//! times a day, which can surface in surprising, opaque ways")

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WatchdogAction {
    /// Everything nominal.
    Ok,
    /// Force a host restart.
    Restart,
    /// Page the on-call.
    Alert,
    /// Dump stack traces for debugging.
    DumpStacks,
}

#[derive(Clone, Debug)]
pub struct WatchdogOptions {
    /// A step taking longer than `max_step_factor` x the rolling median is
    /// a hang.
    pub max_step_factor: f64,
    /// Absolute ceiling regardless of history (catches first-step hangs).
    pub max_step_seconds: f64,
    /// Utilization below this fraction is "low utilization".
    pub min_utilization: f64,
    /// Rolling window length.
    pub window: usize,
    /// Action taken on detection.
    pub action: WatchdogAction,
}

impl Default for WatchdogOptions {
    fn default() -> Self {
        WatchdogOptions {
            max_step_factor: 5.0,
            max_step_seconds: 60.0,
            min_utilization: 0.05,
            window: 32,
            action: WatchdogAction::Restart,
        }
    }
}

/// Step-time/utilization watchdog with a rolling-median baseline.
pub struct Watchdog {
    opts: WatchdogOptions,
    history: Vec<f64>,
    pub trips: u64,
}

impl Watchdog {
    pub fn new(opts: WatchdogOptions) -> Self {
        Watchdog {
            opts,
            history: Vec::new(),
            trips: 0,
        }
    }

    fn median(&self) -> Option<f64> {
        if self.history.is_empty() {
            return None;
        }
        let mut h = self.history.clone();
        h.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(h[h.len() / 2])
    }

    /// Observe one step; returns the action to take.
    pub fn observe_step(&mut self, step_time_s: f64, utilization: f64) -> WatchdogAction {
        let hang = step_time_s > self.opts.max_step_seconds
            || self
                .median()
                .map(|m| step_time_s > m * self.opts.max_step_factor)
                .unwrap_or(false);
        let starved = utilization < self.opts.min_utilization;
        self.history.push(step_time_s);
        if self.history.len() > self.opts.window {
            self.history.remove(0);
        }
        if hang || starved {
            self.trips += 1;
            self.opts.action
        } else {
            WatchdogAction::Ok
        }
    }

    /// Observe a *missing* step (no progress since `elapsed` seconds) —
    /// the hang-detection path for steps that never complete.
    pub fn observe_stall(&mut self, elapsed_s: f64) -> WatchdogAction {
        let limit = self
            .median()
            .map(|m| m * self.opts.max_step_factor)
            .unwrap_or(self.opts.max_step_seconds)
            .min(self.opts.max_step_seconds);
        if elapsed_s > limit {
            self.trips += 1;
            self.opts.action
        } else {
            WatchdogAction::Ok
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wd() -> Watchdog {
        Watchdog::new(WatchdogOptions::default())
    }

    #[test]
    fn nominal_steps_pass() {
        let mut w = wd();
        for _ in 0..50 {
            assert_eq!(w.observe_step(1.0, 0.6), WatchdogAction::Ok);
        }
        assert_eq!(w.trips, 0);
    }

    #[test]
    fn hang_relative_to_median_trips() {
        let mut w = wd();
        for _ in 0..10 {
            w.observe_step(1.0, 0.6);
        }
        assert_eq!(w.observe_step(8.0, 0.6), WatchdogAction::Restart);
        assert_eq!(w.trips, 1);
    }

    #[test]
    fn absolute_ceiling_catches_first_step_hang() {
        let mut w = wd();
        assert_eq!(w.observe_step(120.0, 0.6), WatchdogAction::Restart);
    }

    #[test]
    fn low_utilization_trips() {
        let mut w = wd();
        for _ in 0..5 {
            w.observe_step(1.0, 0.6);
        }
        assert_eq!(w.observe_step(1.0, 0.01), WatchdogAction::Restart);
    }

    #[test]
    fn stall_detection() {
        let mut w = wd();
        for _ in 0..5 {
            w.observe_step(2.0, 0.5);
        }
        assert_eq!(w.observe_stall(5.0), WatchdogAction::Ok);
        assert_eq!(w.observe_stall(30.0), WatchdogAction::Restart);
    }

    #[test]
    fn configurable_action() {
        let mut w = Watchdog::new(WatchdogOptions {
            action: WatchdogAction::Alert,
            ..Default::default()
        });
        assert_eq!(w.observe_step(1000.0, 0.5), WatchdogAction::Alert);
    }

    #[test]
    fn slow_drift_does_not_trip() {
        // gradually slowing steps move the median with them
        let mut w = wd();
        let mut t = 1.0;
        for _ in 0..100 {
            assert_eq!(w.observe_step(t, 0.5), WatchdogAction::Ok);
            t *= 1.02;
        }
    }
}
