//! `repro` — the axlearn-rs leader binary.
//!
//! Subcommands map 1:1 to the paper's experiments (see DESIGN.md §5).
//! (clap is unavailable offline; flags are parsed by hand.)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use axlearn::composer::{aot_compile_check, materialize};
use axlearn::config::mesh_rules::paper_appendix_a_rules;
use axlearn::config::registry::trainer_for_preset;
use axlearn::experiments;
use axlearn::runtime::{Manifest, RuntimeClient};
use axlearn::trainer::{train, SyntheticCorpus, TrainerOptions};

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "aot-check" => cmd_aot_check(&args),
        "table2" => cmd_table2(&args),
        "table3" => {
            println!("Table 3 — training performance (simulated testbeds; see DESIGN.md §2)\n");
            println!("{}", experiments::render_table3(&experiments::table3()));
            Ok(())
        }
        "table4" => cmd_table4(&args),
        "fig4" => {
            println!("Figure 4 — weak scaling on TPU v5p (simulated)\n");
            println!("{}", experiments::render_fig4(&experiments::fig4()));
            Ok(())
        }
        "fig5" => cmd_fig5(&args),
        "recovery" => cmd_recovery(&args),
        "goodput" => cmd_goodput(&args),
        "kernels" => cmd_kernels(),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "repro — axlearn-rs experiment driver
  train --preset tiny|small|base100m [--moe] [--steps N] [--seed S]
        [--checkpoint-every N] [--resume] [--csv FILE] [--eval-every N]
        [--profile] [--corpus markov|uniform|text] [--replicas N]
  serve [--requests N] [--rate R]
  aot-check --preset P --target INSTANCE --chips N
  table2 [--sweep1000]     table3     table4 [--requests N]
  fig4     fig5 [--requests N]     recovery [--chips N]
  goodput [--rate F] [--steps N]     kernels";

fn open_runtime() -> Result<(Arc<RuntimeClient>, Manifest)> {
    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    Ok((client, manifest))
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.get("preset").unwrap_or("tiny").to_string();
    let artifact = if args.has("moe") {
        format!("{preset}_moe")
    } else {
        preset.clone()
    };
    let (client, manifest) = open_runtime()?;
    let art = manifest.get(&format!("{artifact}_train_step"))?;
    let vocab = art.hyper["vocab_size"] as usize;
    let (batch, seq) = (art.batch, art.seq);

    // multi-replica data parallelism (real sessions + collective sync)
    let replicas = args.get_u64("replicas", 1) as usize;
    if replicas > 1 {
        let out = axlearn::distributed::train_data_parallel(
            client,
            &manifest,
            &axlearn::distributed::DataParallelOptions {
                artifact: artifact.clone(),
                replicas,
                steps: args.get_u64("steps", 50),
                sync_every: args.get_u64("sync-every", 10),
                seed: args.get_u64("seed", 0) as i32,
            },
        )?;
        println!(
            "data-parallel x{replicas}: losses {:?} | divergence after sync {:.2e} | {} syncs",
            out.final_losses, out.replica_divergence, out.syncs
        );
        return Ok(());
    }

    let corpus_kind = match args.get("corpus").unwrap_or("markov") {
        "uniform" => axlearn::trainer::input::CorpusKind::Uniform,
        "text" => axlearn::trainer::input::CorpusKind::Text,
        _ => axlearn::trainer::input::CorpusKind::Markov,
    };
    let mut corpus = SyntheticCorpus::new(
        corpus_kind,
        vocab,
        batch,
        seq,
        args.get_u64("seed", 0),
    );
    let opts = TrainerOptions {
        artifact: artifact.clone(),
        max_steps: args.get_u64("steps", 50),
        seed: args.get_u64("seed", 0) as i32,
        log_every: args.get_u64("log-every", 10),
        checkpoint_every: args.get_u64("checkpoint-every", 0),
        checkpoint: axlearn::checkpoint::CheckpointerOptions {
            dir: std::path::PathBuf::from(
                args.get("checkpoint-dir").unwrap_or("checkpoints").to_string(),
            ),
            ..Default::default()
        },
        sdc_every: args.get_u64("sdc-every", 0),
        eval_every: args.get_u64("eval-every", 0),
        resume: args.has("resume"),
        profile: args.has("profile"),
    };
    eprintln!(
        "training {} for {} steps (batch {batch} x seq {seq}, vocab {vocab})",
        artifact, opts.max_steps
    );
    let outcome = train(client, &manifest, &mut corpus, &opts)?;
    for r in outcome.metrics.records.iter().step_by(opts.log_every.max(1) as usize) {
        println!("step {:>5}  loss {:.4}  ({:.2}s)", r.step, r.loss, r.step_time_s);
    }
    println!(
        "\nloss {:.4} -> {:.4} over {} steps | {:.0} tokens/s | corpus floor ~{:.2} nats",
        outcome.first_loss,
        outcome.final_loss,
        outcome.final_step,
        outcome.metrics.tokens_per_second(),
        corpus.entropy_floor(),
    );
    println!("loss curve: {}", outcome.metrics.sparkline(60));
    if let Some(csv) = args.get("csv") {
        outcome.metrics.write_csv(std::path::Path::new(csv))?;
        println!("wrote {csv}");
    }
    if let Some(step) = outcome.resumed_from {
        println!("(resumed from checkpoint at step {step})");
    }
    for e in &outcome.evals {
        println!("eval @ step {:>5}: loss {:.4}", e.step, e.eval_loss);
    }
    if let Some(report) = &outcome.profile_report {
        println!("
profile:
{report}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (client, manifest) = open_runtime()?;
    let n = args.get_u64("requests", 16) as usize;
    let (rows, ratios) = experiments::table4_local(&manifest, client, n)?;
    println!("{}", experiments::render_table4(&rows));
    println!(
        "measured scheduling ratios: TTFT x{:.2}, TPOT x{:.2}",
        ratios.0, ratios.1
    );
    Ok(())
}

fn cmd_aot_check(args: &Args) -> Result<()> {
    let preset = args.get("preset").unwrap_or("small");
    let target = args.get("target").unwrap_or("tpu-v5e-256-4");
    let chips = args.get_u64("chips", 1024) as usize;
    let trainer_cfg = trainer_for_preset(preset)?;
    let rules = paper_appendix_a_rules();
    let plan = materialize(&trainer_cfg, target, chips, &rules)?;
    println!(
        "plan: artifact={} strategy={:?} remat={} quant={} kernel={}",
        plan.artifact, plan.strategy, plan.remat_policy, plan.quantization, plan.kernel_backend
    );
    let chip = axlearn::perfmodel::chips::by_instance_type(target)
        .context("unknown instance type for AOT check")?;
    let report = aot_compile_check(&plan, &chip, None)?;
    println!(
        "AOT check: {} | HBM {:.2}/{:.0} GB | step {:.3}s | MFU {:.1}% | remat {}",
        report.message,
        report.hbm_used_bytes / 1e9,
        report.hbm_capacity / 1e9,
        report.predicted_step_time_s,
        report.predicted_mfu * 100.0,
        report.remat_policy
    );
    if !report.fits {
        bail!("AOT compile check failed (OOM) — caught before any accelerator was provisioned");
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    println!("Table 2 — LoC-complexity (measured on executable integration models)\n");
    println!("{}", axlearn::loc::harness::render_table2(&axlearn::loc::table2()));
    if args.has("sweep1000") {
        let (swapped, changed) = axlearn::loc::harness::sweep_experiments(1000);
        println!("MoE swap over 1000 experiment configs: {swapped} swaps, {changed} existing-module changes");
    }
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    let (client, manifest) = open_runtime()?;
    let n = args.get_u64("requests", 16) as usize;
    println!("Table 4 — inference latency\n-- local measured (real CPU PJRT, small model):");
    let (rows, ratios) = experiments::table4_local(&manifest, client, n)?;
    println!("{}", experiments::render_table4(&rows));
    println!("-- projected at paper scale (analytic + measured scheduling ratios):");
    println!("{}", experiments::render_table4(&experiments::table4_projected(ratios)));
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let (client, manifest) = open_runtime()?;
    let n = args.get_u64("requests", 12) as usize;
    let rates = [0.5, 1.0, 2.0, 4.0, 8.0];
    println!("Figure 5 — throughput vs request rate (local, real CPU PJRT)\n");
    let pts = experiments::fig5_local(&manifest, client, &rates, n)?;
    println!("{}", experiments::render_fig5(&pts));
    Ok(())
}

fn cmd_recovery(args: &Args) -> Result<()> {
    let chips = args.get_u64("chips", 32_768) as usize;
    println!("§5 restart-time experiment at {chips} chips\n");
    for o in axlearn::distributed::recovery_experiment(chips)? {
        println!(
            "{:<14} restart {:>7.1} min  (detect {:.1} + reprovision {:.1} + restore {:.1} + recompile {:.1})",
            o.strategy,
            o.restart_minutes,
            o.detection_minutes,
            o.reprovision_minutes,
            o.restore_minutes,
            o.recompile_minutes
        );
    }
    Ok(())
}

fn cmd_goodput(args: &Args) -> Result<()> {
    use axlearn::distributed::{Cluster, ClusterOptions};
    use axlearn::distributed::recovery::RecoveryStrategy;
    let rate = args.get_f64("rate", 0.01);
    let steps = args.get_u64("steps", 2000);
    for (name, strategy) in [
        ("remote-only", RecoveryStrategy::baseline_remote_only()),
        ("axlearn-full", RecoveryStrategy::axlearn_full()),
    ] {
        let out = Cluster::new(ClusterOptions {
            failure_rate: rate,
            recovery: strategy,
            seed: 42,
            ..Default::default()
        })
        .run(steps)?;
        println!(
            "{:<14} goodput {:.1}%  failures {}  mean restart {:.1} min  wall {:.1} h",
            name,
            out.goodput * 100.0,
            out.failures,
            out.mean_restart_time_s / 60.0,
            out.wall_time_s / 3600.0
        );
    }
    Ok(())
}

fn cmd_kernels() -> Result<()> {
    use axlearn::perfmodel::kernels::{best_blocks, FlashConfig};
    println!("L1 flash-attention structural analysis (TPU v5p core model)\n");
    for (q, kv, d) in [(4096u64, 4096u64, 128u64), (8192, 8192, 128), (65536, 65536, 128)] {
        let (bq, bk, a) = best_blocks(q, kv, d);
        println!(
            "seq {q:>6} d {d}: best blocks ({bq},{bk})  VMEM {:.2} MiB  MXU {:.0}%  AI {:.0} flops/B  roofline {:.0}%",
            a.vmem_bytes / 1048576.0,
            a.mxu_utilization * 100.0,
            a.arithmetic_intensity,
            a.roofline_efficiency * 100.0
        );
        let default = FlashConfig {
            block_q: 128,
            block_k: 128,
            head_dim: d,
            q_len: q,
            kv_len: kv,
            elem_bytes: 2.0,
        }
        .analyze();
        println!(
            "             default (128,128): VMEM {:.2} MiB  roofline {:.0}%",
            default.vmem_bytes / 1048576.0,
            default.roofline_efficiency * 100.0
        );
    }
    Ok(())
}
