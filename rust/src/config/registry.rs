//! Component registry: `default_config(klass)` constructors for every
//! module in the system — the Rust twin of each layer's
//! `default_config()` classmethod.
//!
//! The Layer-2 model classes are mirrored here 1:1 (same class names, same
//! child structure as `python/compile/layers.py`) so that the Rust
//! coordinator can compose, rewrite, and golden-test the *same* config
//! trees whose compute lives in the AOT artifacts.  Trainer-side modules
//! (input pipeline, checkpointer, watchdog, …) and the serving stack
//! (compute backends, batching policies, the replica router) exist only
//! here.

use std::collections::BTreeMap;

use once_cell::sync::Lazy;

use super::node::{ConfigError, ConfigNode, Value};

type Ctor = fn() -> ConfigNode;

static REGISTRY: Lazy<BTreeMap<&'static str, Ctor>> = Lazy::new(register_defaults);

/// Build the default config for a registered class.  Unknown class names
/// are a composition error reported to the caller, not a panic.
pub fn default_config(klass: &str) -> Result<ConfigNode, ConfigError> {
    match REGISTRY.get(klass) {
        Some(ctor) => Ok(ctor()),
        None => Err(ConfigError::UnknownClass {
            klass: klass.to_string(),
            registered: registered_classes().iter().map(|s| s.to_string()).collect(),
        }),
    }
}

pub fn is_registered(klass: &str) -> bool {
    REGISTRY.contains_key(klass)
}

pub fn registered_classes() -> Vec<&'static str> {
    REGISTRY.keys().copied().collect()
}

/// Constructor-internal lookup: the classes referenced by `register_defaults`
/// are statically known, so a miss is a registration-table bug.
fn builtin(klass: &str) -> ConfigNode {
    default_config(klass).expect("builtin class is registered")
}

/// The full default-config table.
pub fn register_defaults() -> BTreeMap<&'static str, Ctor> {
    let mut m: BTreeMap<&'static str, Ctor> = BTreeMap::new();

    // ---- layer library (mirrors python/compile/layers.py) ----
    m.insert("Linear", || {
        ConfigNode::new("Linear")
            .field("input_dim", Value::Null)
            .field("output_dim", Value::Null)
            .field("use_bias", Value::Bool(false))
            .field(
                "param_partition_spec",
                Value::StrList(vec!["fsdp".into(), "model".into()]),
            )
    });
    m.insert("Embedding", || {
        ConfigNode::new("Embedding")
            .field("num_embeddings", Value::Null)
            .field("dim", Value::Null)
    });
    m.insert("RMSNorm", || {
        ConfigNode::new("RMSNorm")
            .field("input_dim", Value::Null)
            .field("eps", Value::Float(1e-6))
    });
    m.insert("RotaryEmbedding", || {
        ConfigNode::new("RotaryEmbedding").field("theta", Value::Float(10000.0))
    });
    m.insert("NoPositionalEmbedding", || ConfigNode::new("NoPositionalEmbedding"));
    m.insert("AttentionLayer", || {
        ConfigNode::new("AttentionLayer")
            .field("input_dim", Value::Null)
            .field("num_heads", Value::Null)
            .field("head_dim", Value::Null)
            .field("pos_emb", Value::Config(builtin("RotaryEmbedding")))
            .field("kernel", Value::Str("flash".into()))
            .field("qkv_proj", Value::Config(builtin("Linear")))
            .field("out_proj", Value::Config(builtin("Linear")))
    });
    m.insert("FlashAttentionLayer", || {
        // Drop-in replacement for AttentionLayer with backend dispatch
        // (paper §4.2): the `backend` field selects cudnn/nki/pallas.
        let mut c = builtin("AttentionLayer");
        c.klass = "FlashAttentionLayer".into();
        c.field("backend", Value::Str("auto".into()))
            .field("block_q", Value::Int(128))
            .field("block_k", Value::Int(128))
    });
    m.insert("FeedForward", || {
        ConfigNode::new("FeedForward")
            .field("input_dim", Value::Null)
            .field("hidden_dim", Value::Null)
            .field("activation", Value::StrList(vec!["linear".into(), "nn.silu".into()]))
            .field("linear", Value::Config(builtin("Linear")))
    });
    m.insert("MoE", || {
        ConfigNode::new("MoE")
            .field("input_dim", Value::Null)
            .field("hidden_dim", Value::Null)
            .field("num_experts", Value::Int(8))
            .field("top_k", Value::Int(2))
            .field("aux_loss_weight", Value::Float(0.01))
            .field("linear", Value::Config(builtin("Linear")))
    });
    m.insert("TransformerLayer", || {
        ConfigNode::new("TransformerLayer")
            .field("input_dim", Value::Null)
            .field("self_attention", Value::Config(builtin("AttentionLayer")))
            .field("feed_forward", Value::Config(builtin("FeedForward")))
            .field("norm", Value::Config(builtin("RMSNorm")))
            .field("remat_spec", Value::Str("none".into()))
    });
    m.insert("Decoder", || {
        ConfigNode::new("Decoder")
            .field("vocab_size", Value::Null)
            .field("model_dim", Value::Null)
            .field("num_layers", Value::Null)
            .field("emb", Value::Config(builtin("Embedding")))
            .field("layer", Value::Config(builtin("TransformerLayer")))
            .field("output_norm", Value::Config(builtin("RMSNorm")))
            .field("tied_lm_head", Value::Bool(true))
    });
    m.insert("CausalLM", || {
        ConfigNode::new("CausalLM")
            .field("decoder", Value::Config(builtin("Decoder")))
            .field("z_loss_weight", Value::Float(0.0))
            .field("seq_len", Value::Null)
    });

    // ---- learner ----
    m.insert("AdamW", || {
        ConfigNode::new("AdamW")
            .field("learning_rate", Value::Float(3e-4))
            .field("beta1", Value::Float(0.9))
            .field("beta2", Value::Float(0.95))
            .field("weight_decay", Value::Float(0.01))
            .field("grad_clip", Value::Float(1.0))
            .field("warmup_steps", Value::Int(100))
    });

    // ---- input pipeline ----
    m.insert("SyntheticLmInput", || {
        ConfigNode::new("SyntheticLmInput")
            .field("batch_size", Value::Null)
            .field("seq_len", Value::Null)
            .field("vocab_size", Value::Null)
            .field("seed", Value::Int(0))
            .field("corpus", Value::Str("markov".into())) // markov | uniform | text
    });

    // ---- checkpointer ----
    m.insert("Checkpointer", || {
        ConfigNode::new("Checkpointer")
            .field("dir", Value::Str("checkpoints".into()))
            .field("every_n_steps", Value::Int(100))
            .field("keep_last", Value::Int(3))
            .field("async_save", Value::Bool(true))
            .field("max_concurrent_shards", Value::Int(4))
            .field("data_sharded", Value::Bool(true))
            .field("storage", Value::Config(builtin("LocalStorage")))
    });
    m.insert("LocalStorage", || {
        ConfigNode::new("LocalStorage").field("root", Value::Str(".".into()))
    });
    m.insert("MultiTierCheckpointer", || {
        let mut c = builtin("Checkpointer");
        c.klass = "MultiTierCheckpointer".into();
        c.field("local_every_n_steps", Value::Int(10))
            .field("remote_every_n_steps", Value::Int(100))
            .field("local_dir", Value::Str("local_ckpt".into()))
    });

    // ---- runtime / resiliency ----
    m.insert("Watchdog", || {
        ConfigNode::new("Watchdog")
            .field("max_step_seconds", Value::Float(60.0))
            .field("min_utilization", Value::Float(0.05))
            .field("check_every_n_steps", Value::Int(10))
            .field("action", Value::Str("restart".into())) // restart | alert | dump
    });
    m.insert("SdcChecker", || {
        ConfigNode::new("SdcChecker")
            .field("every_n_steps", Value::Int(500))
            .field("repeat_collectives", Value::Int(3))
            .field("alternate_cores", Value::Bool(true))
    });

    // ---- serving: compute backends (ComputeBackend implementations) ----
    m.insert("PjrtBackend", || {
        ConfigNode::new("PjrtBackend").field("preset", Value::Str("serve".into()))
    });
    m.insert("AnalyticBackend", || {
        ConfigNode::new("AnalyticBackend")
            .field("chip", Value::Str("tpu-v5p-8".into())) // instance-type prefix
            .field("chips", Value::Int(8))
            .field("model", Value::Str("llama2_7b".into()))
            .field("weight_bytes_per_param", Value::Float(2.0))
    });
    m.insert("MockBackend", || {
        ConfigNode::new("MockBackend")
            .field("prefill_base_s", Value::Float(2e-3))
            .field("prefill_per_token_s", Value::Float(1e-5))
            .field("decode_round_s", Value::Float(4e-3))
            .field("vocab", Value::Int(2048))
    });

    // ---- serving: scheduling policies ----
    m.insert("ContinuousBatchingPolicy", || {
        ConfigNode::new("ContinuousBatchingPolicy")
            .field("slots", Value::Int(8))
            .field("kv_pages", Value::Int(1024))
            .field("page_tokens", Value::Int(16))
            // seconds of queue wait per priority-class promotion; 0.0 is
            // strict FCFS (see serving::batcher)
            .field("aging_s", Value::Float(0.25))
    });
    m.insert("StaticBatchingPolicy", || {
        ConfigNode::new("StaticBatchingPolicy")
            .field("batch_size", Value::Int(8))
            .field("compile_stall_s", Value::Float(2.0))
    });

    // ---- serving: the multi-replica router (root serve module) ----
    m.insert("ServeRouter", || {
        ConfigNode::new("ServeRouter")
            .field("replicas", Value::Int(2))
            .field("spares", Value::Int(1))
            .field("backend", Value::Config(builtin("MockBackend")))
            .field("policy", Value::Config(builtin("ContinuousBatchingPolicy")))
    });

    // ---- serving: the unified disaggregated-serving spec ----
    // One spec drives pool membership, shard layout, and the lowered
    // collective schedule (serving::spec) — the serving counterpart of
    // MeshTrainer's plan.  The serve-* mesh rules rewrite the pool and
    // shard fields from the instance-type string.
    m.insert("ServeSpec", || {
        ConfigNode::new("ServeSpec")
            .field("tp", Value::Int(1))
            .field("ep", Value::Int(1))
            .field("prefill_replicas", Value::Int(1))
            .field("decode_replicas", Value::Int(2))
            .field("spares", Value::Int(0))
            .field("num_experts", Value::Int(1))
            .field("active_experts", Value::Int(1))
            .field("capacity_factor", Value::Float(1.25))
            .field("max_seq", Value::Int(1024))
            .field("hidden_dim", Value::Int(512))
            // KV-cache bytes per token across all layers (K and V)
            .field("kv_bytes_per_token", Value::Float(64.0))
            // instance type selects the interconnect cost model
            .field("instance_type", Value::Str("cpu-local".into()))
            // static schedule verifier gate at lowering time
            .field("verify", Value::Bool(true))
            .field("policy", Value::Config(builtin("ContinuousBatchingPolicy")))
    });

    // ---- training: compute backends (TrainBackend implementations) ----
    m.insert("PjrtTrainBackend", || {
        ConfigNode::new("PjrtTrainBackend").field("artifact", Value::Str("tiny".into()))
    });
    m.insert("MockTrainBackend", || {
        ConfigNode::new("MockTrainBackend")
            .field("dim", Value::Int(64))
            .field("batch", Value::Int(2))
            .field("seq", Value::Int(32))
            .field("vocab", Value::Int(256))
            .field("lr", Value::Float(0.2))
    });

    // ---- training: mesh-sharded execution (wraps any train backend) ----
    m.insert("MeshTrainer", || {
        ConfigNode::new("MeshTrainer")
            .field("mesh_shape", Value::IntList(vec![1, 2, 2]))
            .field(
                "mesh_axis_names",
                Value::StrList(vec!["data".into(), "fsdp".into(), "model".into()]),
            )
            // mesh axes that shard parameters (the resolved sharding
            // plan); axes left out replicate and fold into DP sync
            .field("shard_axes", Value::StrList(vec!["fsdp".into(), "model".into()]))
            // microbatches per step when the mesh has a pipeline axis
            // (raised to the stage count if set lower)
            .field("microbatches", Value::Int(1))
            // "1f1b" | "gpipe" — the microbatch schedule for pipeline axes
            .field("pipeline_schedule", Value::Str("1f1b".into()))
            // MoE bank for an expert mesh axis: the expert degree must
            // divide num_experts; active_experts is the router top-k
            .field("num_experts", Value::Int(1))
            .field("active_experts", Value::Int(1))
            .field("capacity_factor", Value::Float(1.25))
            // instance type selects the interconnect cost model
            .field("instance_type", Value::Str("cpu-local".into()))
            // simulator worker threads (wall-clock only: results are
            // bit-identical at any value)
            .field("sim_threads", Value::Int(1))
            // static schedule verifier (composer::verify) at
            // construction + init; off only to test its failure paths
            .field("verify", Value::Bool(true))
            .field("backend", Value::Config(builtin("MockTrainBackend")))
    });

    // ---- training: fleet recovery strategy ----
    m.insert("FleetRecovery", || {
        ConfigNode::new("FleetRecovery")
            .field("spares", Value::Int(1))
            .field("local_every_n_steps", Value::Int(4))
            .field("remote_every_n_steps", Value::Int(8))
            .field("local_dir", Value::Str("fleet_ckpt/local".into()))
            .field("remote_dir", Value::Str("fleet_ckpt/remote".into()))
            .field("restart_overhead_s", Value::Float(5.0))
            .field("reprovision_s", Value::Float(60.0))
    });

    // ---- training: the fault-tolerant fleet trainer (root module) ----
    m.insert("FleetTrainer", || {
        ConfigNode::new("FleetTrainer")
            .field("replicas", Value::Int(2))
            .field("steps", Value::Int(16))
            .field("sync_every", Value::Int(4))
            .field("seed", Value::Int(0))
            .field("step_time_s", Value::Float(1.0))
            .field("backend", Value::Config(builtin("MockTrainBackend")))
            .field("recovery", Value::Config(builtin("FleetRecovery")))
            .field("failure_rate_per_host_hour", Value::Float(0.0))
            .field("hosts_per_replica", Value::Int(8))
            .field("failure_seed", Value::Int(0))
    });

    // ---- trainer (root module) ----
    m.insert("Trainer", || {
        ConfigNode::new("Trainer")
            .field("model", Value::Config(builtin("CausalLM")))
            .field("learner", Value::Config(builtin("AdamW")))
            .field("input", Value::Config(builtin("SyntheticLmInput")))
            .field("checkpointer", Value::Config(builtin("Checkpointer")))
            .field("watchdog", Value::Config(builtin("Watchdog")))
            .field("sdc_checker", Value::Config(builtin("SdcChecker")))
            .field("max_steps", Value::Int(100))
            .field("seed", Value::Int(0))
            .field("mesh_shape", Value::IntList(vec![1, 1]))
            .field("mesh_axis_names", Value::StrList(vec!["data".into(), "model".into()]))
            // microbatches per step for pipeline mesh axes (the composer
            // raises it to the stage count when a mesh rule adds stages)
            .field("microbatches", Value::Int(1))
            .field("pipeline_schedule", Value::Str("1f1b".into())) // | "gpipe"
            // per-expert token headroom when the mesh has an expert axis
            // (the MoE bank itself lives on model.decoder.layer.feed_forward)
            .field("capacity_factor", Value::Float(1.25))
            .field("remat_policy", Value::Str("none".into()))
            .field("quantization", Value::Str("none".into())) // none | int8 | fp8
            .field("preset", Value::Str("tiny".into()))
            .field("moe", Value::Bool(false))
            .field("rope", Value::Bool(true))
            .field("log_every_n_steps", Value::Int(10))
    });

    m
}

// ---------------------------------------------------------------------------
// Preset experiment configs (the "experiments" of §7.1).
// ---------------------------------------------------------------------------

const PRESETS: [&str; 4] = ["tiny", "small", "base100m", "serve"];

/// Build a trainer config for a model preset.  Mirrors
/// `python/compile/configs.PRESETS`, which defines the artifact shapes.
/// Unknown presets are reported as [`ConfigError::UnknownPreset`].
pub fn trainer_for_preset(preset: &str) -> Result<ConfigNode, ConfigError> {
    let (vocab, dim, layers, heads, head_dim, ffn, seq, batch) = match preset {
        "tiny" => (256, 64, 2, 4, 16, 192, 32, 2),
        "small" => (2048, 256, 4, 4, 64, 704, 128, 4),
        "base100m" => (8192, 768, 12, 12, 64, 2048, 256, 4),
        "serve" => (2048, 256, 4, 4, 64, 704, 384, 8),
        other => {
            return Err(ConfigError::UnknownPreset {
                preset: other.to_string(),
                known: PRESETS.iter().map(|s| s.to_string()).collect(),
            })
        }
    };
    let mut t = default_config("Trainer")?;
    t.set("preset", Value::Str(preset.into()))?;
    {
        let dec = t.at_path_mut("model.decoder")?;
        dec.set("vocab_size", Value::Int(vocab))?;
        dec.set("model_dim", Value::Int(dim))?;
        dec.set("num_layers", Value::Int(layers))?;
    }
    {
        let attn = t.at_path_mut("model.decoder.layer.self_attention")?;
        attn.set("num_heads", Value::Int(heads))?;
        attn.set("head_dim", Value::Int(head_dim))?;
    }
    {
        let ff = t.at_path_mut("model.decoder.layer.feed_forward")?;
        ff.set("hidden_dim", Value::Int(ffn))?;
    }
    t.at_path_mut("model")?.set("seq_len", Value::Int(seq))?;
    {
        let input = t.at_path_mut("input")?;
        input.set("batch_size", Value::Int(batch))?;
        input.set("seq_len", Value::Int(seq))?;
        input.set("vocab_size", Value::Int(vocab))?;
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_classes_constructible() {
        for klass in registered_classes() {
            let cfg = default_config(klass).unwrap();
            assert_eq!(cfg.klass, klass);
        }
    }

    #[test]
    fn trainer_tree_is_hierarchical() {
        let t = default_config("Trainer").unwrap();
        assert_eq!(t.at_path("model.decoder.layer.self_attention.pos_emb").unwrap().klass, "RotaryEmbedding");
        // strict encapsulation: the trainer has no flattened RoPE field
        assert!(!t.has_field("rope_theta"));
        assert!(!t.child("model").unwrap().has_field("rope_theta"));
    }

    #[test]
    fn presets_build() {
        for p in PRESETS {
            let t = trainer_for_preset(p).unwrap();
            assert!(t.at_path("model.decoder").unwrap().get_int("vocab_size").unwrap() > 0);
        }
    }

    #[test]
    fn unknown_class_is_an_error_not_a_panic() {
        let err = default_config("Bogus").unwrap_err();
        assert!(matches!(err, ConfigError::UnknownClass { .. }));
        assert!(err.to_string().contains("Bogus"));
        assert!(err.to_string().contains("Trainer")); // lists what IS registered
    }

    #[test]
    fn unknown_preset_is_an_error_not_a_panic() {
        let err = trainer_for_preset("llama9000").unwrap_err();
        assert!(matches!(err, ConfigError::UnknownPreset { .. }));
        assert!(err.to_string().contains("llama9000"));
        assert!(err.to_string().contains("base100m"));
    }

    #[test]
    fn flash_attention_is_dropin_for_attention() {
        // same field superset => interface-compatible (§4.2 custom kernels)
        let base = default_config("AttentionLayer").unwrap();
        let flash = default_config("FlashAttentionLayer").unwrap();
        for f in base.field_names() {
            assert!(flash.has_field(&f), "FlashAttentionLayer missing {f}");
        }
    }

    #[test]
    fn fleet_trainer_tree_is_hierarchical() {
        // backend × replica-count × recovery-strategy compose like trainer
        // configs: the fleet never sees backend or tier internals
        let f = default_config("FleetTrainer").unwrap();
        assert_eq!(f.child("backend").unwrap().klass, "MockTrainBackend");
        assert_eq!(f.child("recovery").unwrap().klass, "FleetRecovery");
        assert!(!f.has_field("dim")); // strict encapsulation
        assert!(!f.has_field("local_every_n_steps"));
        // swapping the train backend is a one-field config change
        let mut f2 = f.clone();
        f2.set(
            "backend",
            Value::Config(default_config("PjrtTrainBackend").unwrap()),
        )
        .unwrap();
        assert_eq!(f2.child("backend").unwrap().klass, "PjrtTrainBackend");
    }

    #[test]
    fn mesh_trainer_tree_is_hierarchical() {
        // mesh-shape × backend compose like fleet presets: the mesh node
        // never sees backend internals, and fleets nest meshes
        let m = default_config("MeshTrainer").unwrap();
        assert_eq!(m.child("backend").unwrap().klass, "MockTrainBackend");
        assert!(!m.has_field("dim")); // strict encapsulation
        let mut fleet = default_config("FleetTrainer").unwrap();
        fleet.set("backend", Value::Config(m)).unwrap();
        assert_eq!(fleet.child("backend").unwrap().klass, "MeshTrainer");
        assert_eq!(
            fleet.at_path("backend.backend").unwrap().klass,
            "MockTrainBackend"
        );
    }

    #[test]
    fn serve_router_tree_is_hierarchical() {
        // backend × policy × replica-count compose like trainer configs:
        // the router never sees backend internals (strict encapsulation)
        let r = default_config("ServeRouter").unwrap();
        assert_eq!(r.child("backend").unwrap().klass, "MockBackend");
        assert_eq!(r.child("policy").unwrap().klass, "ContinuousBatchingPolicy");
        assert!(!r.has_field("decode_round_s"));
        assert!(!r.has_field("slots"));
        // swapping the backend is a one-field config change
        let mut r2 = r.clone();
        r2.set("backend", Value::Config(default_config("AnalyticBackend").unwrap()))
            .unwrap();
        assert_eq!(r2.child("backend").unwrap().klass, "AnalyticBackend");
    }
}
