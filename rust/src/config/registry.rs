//! Component registry: `default_config(klass)` constructors for every
//! module in the system — the Rust twin of each layer's
//! `default_config()` classmethod.
//!
//! The Layer-2 model classes are mirrored here 1:1 (same class names, same
//! child structure as `python/compile/layers.py`) so that the Rust
//! coordinator can compose, rewrite, and golden-test the *same* config
//! trees whose compute lives in the AOT artifacts.  Trainer-side modules
//! (input pipeline, checkpointer, watchdog, …) exist only here.

use std::collections::BTreeMap;

use once_cell::sync::Lazy;

use super::node::{ConfigNode, Value};

type Ctor = fn() -> ConfigNode;

static REGISTRY: Lazy<BTreeMap<&'static str, Ctor>> = Lazy::new(register_defaults);

/// Build the default config for a registered class. Panics on unknown
/// class names (a config referencing an unregistered class is a
/// programming error, caught in tests).
pub fn default_config(klass: &str) -> ConfigNode {
    match REGISTRY.get(klass) {
        Some(ctor) => ctor(),
        None => panic!(
            "default_config: unknown class {klass:?}; registered: {:?}",
            REGISTRY.keys().collect::<Vec<_>>()
        ),
    }
}

pub fn is_registered(klass: &str) -> bool {
    REGISTRY.contains_key(klass)
}

pub fn registered_classes() -> Vec<&'static str> {
    REGISTRY.keys().copied().collect()
}

/// The full default-config table.
pub fn register_defaults() -> BTreeMap<&'static str, Ctor> {
    let mut m: BTreeMap<&'static str, Ctor> = BTreeMap::new();

    // ---- layer library (mirrors python/compile/layers.py) ----
    m.insert("Linear", || {
        ConfigNode::new("Linear")
            .field("input_dim", Value::Null)
            .field("output_dim", Value::Null)
            .field("use_bias", Value::Bool(false))
            .field(
                "param_partition_spec",
                Value::StrList(vec!["fsdp".into(), "model".into()]),
            )
    });
    m.insert("Embedding", || {
        ConfigNode::new("Embedding")
            .field("num_embeddings", Value::Null)
            .field("dim", Value::Null)
    });
    m.insert("RMSNorm", || {
        ConfigNode::new("RMSNorm")
            .field("input_dim", Value::Null)
            .field("eps", Value::Float(1e-6))
    });
    m.insert("RotaryEmbedding", || {
        ConfigNode::new("RotaryEmbedding").field("theta", Value::Float(10000.0))
    });
    m.insert("NoPositionalEmbedding", || ConfigNode::new("NoPositionalEmbedding"));
    m.insert("AttentionLayer", || {
        ConfigNode::new("AttentionLayer")
            .field("input_dim", Value::Null)
            .field("num_heads", Value::Null)
            .field("head_dim", Value::Null)
            .field("pos_emb", Value::Config(default_config("RotaryEmbedding")))
            .field("kernel", Value::Str("flash".into()))
            .field("qkv_proj", Value::Config(default_config("Linear")))
            .field("out_proj", Value::Config(default_config("Linear")))
    });
    m.insert("FlashAttentionLayer", || {
        // Drop-in replacement for AttentionLayer with backend dispatch
        // (paper §4.2): the `backend` field selects cudnn/nki/pallas.
        let mut c = default_config("AttentionLayer");
        c.klass = "FlashAttentionLayer".into();
        c.field("backend", Value::Str("auto".into()))
            .field("block_q", Value::Int(128))
            .field("block_k", Value::Int(128))
    });
    m.insert("FeedForward", || {
        ConfigNode::new("FeedForward")
            .field("input_dim", Value::Null)
            .field("hidden_dim", Value::Null)
            .field("activation", Value::StrList(vec!["linear".into(), "nn.silu".into()]))
            .field("linear", Value::Config(default_config("Linear")))
    });
    m.insert("MoE", || {
        ConfigNode::new("MoE")
            .field("input_dim", Value::Null)
            .field("hidden_dim", Value::Null)
            .field("num_experts", Value::Int(8))
            .field("top_k", Value::Int(2))
            .field("aux_loss_weight", Value::Float(0.01))
            .field("linear", Value::Config(default_config("Linear")))
    });
    m.insert("TransformerLayer", || {
        ConfigNode::new("TransformerLayer")
            .field("input_dim", Value::Null)
            .field("self_attention", Value::Config(default_config("AttentionLayer")))
            .field("feed_forward", Value::Config(default_config("FeedForward")))
            .field("norm", Value::Config(default_config("RMSNorm")))
            .field("remat_spec", Value::Str("none".into()))
    });
    m.insert("Decoder", || {
        ConfigNode::new("Decoder")
            .field("vocab_size", Value::Null)
            .field("model_dim", Value::Null)
            .field("num_layers", Value::Null)
            .field("emb", Value::Config(default_config("Embedding")))
            .field("layer", Value::Config(default_config("TransformerLayer")))
            .field("output_norm", Value::Config(default_config("RMSNorm")))
            .field("tied_lm_head", Value::Bool(true))
    });
    m.insert("CausalLM", || {
        ConfigNode::new("CausalLM")
            .field("decoder", Value::Config(default_config("Decoder")))
            .field("z_loss_weight", Value::Float(0.0))
            .field("seq_len", Value::Null)
    });

    // ---- learner ----
    m.insert("AdamW", || {
        ConfigNode::new("AdamW")
            .field("learning_rate", Value::Float(3e-4))
            .field("beta1", Value::Float(0.9))
            .field("beta2", Value::Float(0.95))
            .field("weight_decay", Value::Float(0.01))
            .field("grad_clip", Value::Float(1.0))
            .field("warmup_steps", Value::Int(100))
    });

    // ---- input pipeline ----
    m.insert("SyntheticLmInput", || {
        ConfigNode::new("SyntheticLmInput")
            .field("batch_size", Value::Null)
            .field("seq_len", Value::Null)
            .field("vocab_size", Value::Null)
            .field("seed", Value::Int(0))
            .field("corpus", Value::Str("markov".into())) // markov | uniform | text
    });

    // ---- checkpointer ----
    m.insert("Checkpointer", || {
        ConfigNode::new("Checkpointer")
            .field("dir", Value::Str("checkpoints".into()))
            .field("every_n_steps", Value::Int(100))
            .field("keep_last", Value::Int(3))
            .field("async_save", Value::Bool(true))
            .field("max_concurrent_shards", Value::Int(4))
            .field("data_sharded", Value::Bool(true))
            .field("storage", Value::Config(default_config("LocalStorage")))
    });
    m.insert("LocalStorage", || {
        ConfigNode::new("LocalStorage").field("root", Value::Str(".".into()))
    });
    m.insert("MultiTierCheckpointer", || {
        let mut c = default_config("Checkpointer");
        c.klass = "MultiTierCheckpointer".into();
        c.field("local_every_n_steps", Value::Int(10))
            .field("remote_every_n_steps", Value::Int(100))
            .field("local_dir", Value::Str("local_ckpt".into()))
    });

    // ---- runtime / resiliency ----
    m.insert("Watchdog", || {
        ConfigNode::new("Watchdog")
            .field("max_step_seconds", Value::Float(60.0))
            .field("min_utilization", Value::Float(0.05))
            .field("check_every_n_steps", Value::Int(10))
            .field("action", Value::Str("restart".into())) // restart | alert | dump
    });
    m.insert("SdcChecker", || {
        ConfigNode::new("SdcChecker")
            .field("every_n_steps", Value::Int(500))
            .field("repeat_collectives", Value::Int(3))
            .field("alternate_cores", Value::Bool(true))
    });

    // ---- trainer (root module) ----
    m.insert("Trainer", || {
        ConfigNode::new("Trainer")
            .field("model", Value::Config(default_config("CausalLM")))
            .field("learner", Value::Config(default_config("AdamW")))
            .field("input", Value::Config(default_config("SyntheticLmInput")))
            .field("checkpointer", Value::Config(default_config("Checkpointer")))
            .field("watchdog", Value::Config(default_config("Watchdog")))
            .field("sdc_checker", Value::Config(default_config("SdcChecker")))
            .field("max_steps", Value::Int(100))
            .field("seed", Value::Int(0))
            .field("mesh_shape", Value::IntList(vec![1, 1]))
            .field("mesh_axis_names", Value::StrList(vec!["data".into(), "model".into()]))
            .field("remat_policy", Value::Str("none".into()))
            .field("quantization", Value::Str("none".into())) // none | int8 | fp8
            .field("preset", Value::Str("tiny".into()))
            .field("moe", Value::Bool(false))
            .field("rope", Value::Bool(true))
            .field("log_every_n_steps", Value::Int(10))
    });

    m
}

// ---------------------------------------------------------------------------
// Preset experiment configs (the "experiments" of §7.1).
// ---------------------------------------------------------------------------

/// Build a trainer config for a model preset.  Mirrors
/// `python/compile/configs.PRESETS`, which defines the artifact shapes.
pub fn trainer_for_preset(preset: &str) -> ConfigNode {
    let (vocab, dim, layers, heads, head_dim, ffn, seq, batch) = match preset {
        "tiny" => (256, 64, 2, 4, 16, 192, 32, 2),
        "small" => (2048, 256, 4, 4, 64, 704, 128, 4),
        "base100m" => (8192, 768, 12, 12, 64, 2048, 256, 4),
        "serve" => (2048, 256, 4, 4, 64, 704, 384, 8),
        other => panic!("unknown preset {other:?}"),
    };
    let mut t = default_config("Trainer");
    t.set("preset", Value::Str(preset.into())).unwrap();
    {
        let dec = t.at_path_mut("model.decoder").unwrap();
        dec.set("vocab_size", Value::Int(vocab)).unwrap();
        dec.set("model_dim", Value::Int(dim)).unwrap();
        dec.set("num_layers", Value::Int(layers)).unwrap();
    }
    {
        let attn = t.at_path_mut("model.decoder.layer.self_attention").unwrap();
        attn.set("num_heads", Value::Int(heads)).unwrap();
        attn.set("head_dim", Value::Int(head_dim)).unwrap();
    }
    {
        let ff = t.at_path_mut("model.decoder.layer.feed_forward").unwrap();
        ff.set("hidden_dim", Value::Int(ffn)).unwrap();
    }
    t.at_path_mut("model").unwrap().set("seq_len", Value::Int(seq)).unwrap();
    {
        let input = t.at_path_mut("input").unwrap();
        input.set("batch_size", Value::Int(batch)).unwrap();
        input.set("seq_len", Value::Int(seq)).unwrap();
        input.set("vocab_size", Value::Int(vocab)).unwrap();
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_classes_constructible() {
        for klass in registered_classes() {
            let cfg = default_config(klass);
            assert_eq!(cfg.klass, klass);
        }
    }

    #[test]
    fn trainer_tree_is_hierarchical() {
        let t = default_config("Trainer");
        assert_eq!(t.at_path("model.decoder.layer.self_attention.pos_emb").unwrap().klass, "RotaryEmbedding");
        // strict encapsulation: the trainer has no flattened RoPE field
        assert!(!t.has_field("rope_theta"));
        assert!(!t.child("model").unwrap().has_field("rope_theta"));
    }

    #[test]
    fn presets_build() {
        for p in ["tiny", "small", "base100m", "serve"] {
            let t = trainer_for_preset(p);
            assert!(t.at_path("model.decoder").unwrap().get_int("vocab_size").unwrap() > 0);
        }
    }

    #[test]
    #[should_panic(expected = "unknown class")]
    fn unknown_class_panics() {
        default_config("Bogus");
    }

    #[test]
    fn flash_attention_is_dropin_for_attention() {
        // same field superset => interface-compatible (§4.2 custom kernels)
        let base = default_config("AttentionLayer");
        let flash = default_config("FlashAttentionLayer");
        for f in base.field_names() {
            assert!(flash.has_field(&f), "FlashAttentionLayer missing {f}");
        }
    }
}
