//! Config modifiers (paper §4.2 / Appendix A).
//!
//! A [`ConfigModifier`] is a self-contained rewrite of a trainer config:
//! mesh shape, rematerialization policy, quantization, kernel selection,
//! or an arbitrary path-addressed field set.  Mesh rules
//! ([`super::mesh_rules`]) map accelerator types to ordered lists of
//! modifiers, which is how one experiment config adapts to heterogeneous
//! platforms with zero model-code changes.

use anyhow::{bail, Result};

use super::node::{ConfigNode, Value};
use super::traverse::{replace_config, visit_mut};

/// A rewrite applied to the (trainer) config tree.
pub trait ConfigModifier: Send + Sync {
    /// Human-readable name for logs and golden dumps.
    fn name(&self) -> String;
    /// Apply in place.
    fn apply(&self, cfg: &mut ConfigNode) -> Result<()>;
}

/// Ordered list of modifiers.
pub struct ModifierList(pub Vec<Box<dyn ConfigModifier>>);

impl ModifierList {
    pub fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        for m in &self.0 {
            m.apply(cfg)?;
        }
        Ok(())
    }

    pub fn names(&self) -> Vec<String> {
        self.0.iter().map(|m| m.name()).collect()
    }
}

/// Sets `mesh_shape` / `mesh_axis_names` on the trainer (Appendix A's
/// `MeshShapeModifier`).  A `-1` dim means "fill with remaining devices",
/// resolved by the composer against the target topology.
pub struct MeshShapeModifier {
    pub mesh_shape: Vec<i64>,
    pub mesh_axis_names: Vec<String>,
}

impl MeshShapeModifier {
    pub fn new(shape: &[i64], names: &[&str]) -> Self {
        MeshShapeModifier {
            mesh_shape: shape.to_vec(),
            mesh_axis_names: names.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl ConfigModifier for MeshShapeModifier {
    fn name(&self) -> String {
        format!("MeshShapeModifier{:?}/{:?}", self.mesh_shape, self.mesh_axis_names)
    }

    fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        if self.mesh_shape.len() != self.mesh_axis_names.len() {
            bail!(
                "mesh_shape {:?} and axis names {:?} must have equal rank",
                self.mesh_shape,
                self.mesh_axis_names
            );
        }
        cfg.set("mesh_shape", Value::IntList(self.mesh_shape.clone()))?;
        cfg.set("mesh_axis_names", Value::StrList(self.mesh_axis_names.clone()))?;
        Ok(())
    }
}

/// Sets the rematerialization policy, optionally targeting tagged remat
/// points on specific layers (Appendix A's `RematSpecModifier`).
///
/// Policies (see `composer::remat` for cost semantics):
///   "none" | "full" | "save_qkvo" | "save_linear" | "offload_dots"
pub struct RematSpecModifier {
    pub policy: String,
    /// Config path of the layer(s) to tag; empty = trainer-wide.
    pub target_path: Option<String>,
}

impl RematSpecModifier {
    pub fn new(policy: &str) -> Self {
        RematSpecModifier {
            policy: policy.to_string(),
            target_path: None,
        }
    }

    pub fn at(policy: &str, path: &str) -> Self {
        RematSpecModifier {
            policy: policy.to_string(),
            target_path: Some(path.to_string()),
        }
    }
}

pub const REMAT_POLICIES: &[&str] = &["none", "full", "save_qkvo", "save_linear", "offload_dots"];

impl ConfigModifier for RematSpecModifier {
    fn name(&self) -> String {
        match &self.target_path {
            Some(p) => format!("RematSpecModifier({} @ {p})", self.policy),
            None => format!("RematSpecModifier({})", self.policy),
        }
    }

    fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        if !REMAT_POLICIES.contains(&self.policy.as_str()) {
            bail!("unknown remat policy {:?}; expected one of {REMAT_POLICIES:?}", self.policy);
        }
        match &self.target_path {
            None => {
                cfg.set("remat_policy", Value::Str(self.policy.clone()))?;
            }
            Some(path) => {
                let node = cfg.at_path_mut(path)?;
                if !node.has_field("remat_spec") {
                    bail!("{path}: {} has no remat_spec tag point", node.klass);
                }
                node.set("remat_spec", Value::Str(self.policy.clone()))?;
            }
        }
        Ok(())
    }
}

/// Enables INT8/FP8 quantized training (Appendix A's
/// `INT8ConfigModifier` / `FP8ConfigModifier`).  Implemented as strict
/// encapsulation demands: a *replacement of DotGeneral-bearing layers*
/// is expressed as a trainer-level knob the composer maps onto the
/// quantization-aware artifact/cost model, never as per-layer flags.
pub struct QuantizationModifier {
    pub mode: String, // "int8" | "fp8"
    pub fp8_amax_history_length: i64,
}

impl QuantizationModifier {
    pub fn int8() -> Self {
        QuantizationModifier {
            mode: "int8".into(),
            fp8_amax_history_length: 0,
        }
    }

    pub fn fp8(history: i64) -> Self {
        QuantizationModifier {
            mode: "fp8".into(),
            fp8_amax_history_length: history,
        }
    }
}

impl ConfigModifier for QuantizationModifier {
    fn name(&self) -> String {
        format!("QuantizationModifier({})", self.mode)
    }

    fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        if !["int8", "fp8", "none"].contains(&self.mode.as_str()) {
            bail!("unknown quantization mode {:?}", self.mode);
        }
        cfg.set("quantization", Value::Str(self.mode.clone()))?;
        Ok(())
    }
}

/// Swaps every `AttentionLayer` for `FlashAttentionLayer` with a given
/// backend (paper §4.2: "enabling custom kernels only requires simple
/// configuration changes").
pub struct KernelModifier {
    pub backend: String, // "cudnn" | "nki" | "pallas" | "auto"
}

impl KernelModifier {
    pub fn new(backend: &str) -> Self {
        KernelModifier {
            backend: backend.to_string(),
        }
    }
}

impl ConfigModifier for KernelModifier {
    fn name(&self) -> String {
        format!("KernelModifier({})", self.backend)
    }

    fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        let backend = self.backend.clone();
        let n = replace_config(cfg, "AttentionLayer", &move |old| {
            let mut flash = super::registry::default_config("FlashAttentionLayer")
                .expect("FlashAttentionLayer is registered");
            // carry over the interface fields (input dims etc.)
            for f in old.field_names() {
                let v = old.get(&f).unwrap().clone();
                let _ = flash.set(&f, v);
            }
            flash.set("backend", Value::Str(backend.clone())).unwrap();
            flash
        });
        if n == 0 {
            // Already flash everywhere: just retarget the backend.
            let mut count = 0;
            visit_mut(cfg, &mut |_, node| {
                if node.klass == "FlashAttentionLayer" {
                    node.set("backend", Value::Str(self.backend.clone())).unwrap();
                    count += 1;
                }
            });
            if count == 0 {
                bail!("KernelModifier: no attention layers found");
            }
        }
        Ok(())
    }
}

/// Generic path-addressed field set — the escape hatch that keeps
/// "arbitrary config modifications expressible as modifiers" (§4.2).
pub struct SetFieldModifier {
    pub path: String,
    pub field: String,
    pub value: Value,
}

impl SetFieldModifier {
    pub fn new(path: &str, field: &str, value: Value) -> Self {
        SetFieldModifier {
            path: path.to_string(),
            field: field.to_string(),
            value,
        }
    }
}

impl ConfigModifier for SetFieldModifier {
    fn name(&self) -> String {
        format!("SetFieldModifier({}.{} = {})", self.path, self.field, self.value)
    }

    fn apply(&self, cfg: &mut ConfigNode) -> Result<()> {
        let node = if self.path.is_empty() {
            cfg
        } else {
            cfg.at_path_mut(&self.path)?
        };
        node.set(&self.field, self.value.clone())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registry::trainer_for_preset;

    #[test]
    fn mesh_shape_modifier() {
        let mut t = trainer_for_preset("tiny").unwrap();
        MeshShapeModifier::new(&[-1, 256], &["data", "fsdp"]).apply(&mut t).unwrap();
        assert_eq!(t.get_int_list("mesh_shape").unwrap(), vec![-1, 256]);
        assert_eq!(t.get_str_list("mesh_axis_names").unwrap(), vec!["data", "fsdp"]);
    }

    #[test]
    fn mesh_rank_mismatch_rejected() {
        let mut t = trainer_for_preset("tiny").unwrap();
        assert!(MeshShapeModifier::new(&[1, 2], &["data"]).apply(&mut t).is_err());
    }

    #[test]
    fn remat_global_and_targeted() {
        let mut t = trainer_for_preset("tiny").unwrap();
        RematSpecModifier::new("save_qkvo").apply(&mut t).unwrap();
        assert_eq!(t.get_str("remat_policy").unwrap(), "save_qkvo");
        RematSpecModifier::at("offload_dots", "model.decoder.layer").apply(&mut t).unwrap();
        assert_eq!(
            t.at_path("model.decoder.layer").unwrap().get_str("remat_spec").unwrap(),
            "offload_dots"
        );
    }

    #[test]
    fn remat_unknown_policy_rejected() {
        let mut t = trainer_for_preset("tiny").unwrap();
        assert!(RematSpecModifier::new("bogus").apply(&mut t).is_err());
    }

    #[test]
    fn quantization_modifier() {
        let mut t = trainer_for_preset("tiny").unwrap();
        QuantizationModifier::fp8(128).apply(&mut t).unwrap();
        assert_eq!(t.get_str("quantization").unwrap(), "fp8");
    }

    #[test]
    fn kernel_modifier_swaps_attention() {
        let mut t = trainer_for_preset("tiny").unwrap();
        KernelModifier::new("pallas").apply(&mut t).unwrap();
        let attn = t.at_path("model.decoder.layer.self_attention").unwrap();
        assert_eq!(attn.klass, "FlashAttentionLayer");
        assert_eq!(attn.get_str("backend").unwrap(), "pallas");
        // interface fields preserved
        assert!(attn.has_field("num_heads"));
        // applying again just retargets
        KernelModifier::new("cudnn").apply(&mut t).unwrap();
        assert_eq!(
            t.at_path("model.decoder.layer.self_attention").unwrap().get_str("backend").unwrap(),
            "cudnn"
        );
    }

    #[test]
    fn set_field_modifier() {
        let mut t = trainer_for_preset("tiny").unwrap();
        SetFieldModifier::new("learner", "learning_rate", Value::Float(1e-3)).apply(&mut t).unwrap();
        assert_eq!(t.at_path("learner").unwrap().get_float("learning_rate").unwrap(), 1e-3);
    }

    #[test]
    fn modifier_list_applies_in_order() {
        let mut t = trainer_for_preset("tiny").unwrap();
        let list = ModifierList(vec![
            Box::new(MeshShapeModifier::new(&[4, 2], &["fsdp", "model"])),
            Box::new(SetFieldModifier::new("", "remat_policy", Value::Str("full".into()))),
            Box::new(SetFieldModifier::new("", "remat_policy", Value::Str("save_linear".into()))),
        ]);
        list.apply(&mut t).unwrap();
        assert_eq!(t.get_str("remat_policy").unwrap(), "save_linear"); // last wins
        assert_eq!(list.names().len(), 3);
    }
}
