//! Mesh rules (paper Appendix A): instance-type regex -> config modifiers.
//!
//! A [`MeshRules`] table lets one experiment config adapt to heterogeneous
//! platforms: launching on `tpu-v5e-256-4` matches the `tpu-v5e-256-*`
//! rule and applies FSDP-within-slice + INT8 + dot offload, while
//! `gpu-H100-64` matches the H100 rule and applies 8-way TP + FP8.  No
//! model code changes — the paper's core heterogeneity mechanism.

use anyhow::Result;
use regex::Regex;

use super::modifier::{ConfigModifier, ModifierList};
use super::node::ConfigNode;

/// A rule body computed from the matched instance-type string itself —
/// the `planner` rule kind, where the mesh is searched for at apply
/// time rather than written down in the rule table.
pub type DynamicRule = Box<dyn Fn(&str, &mut ConfigNode) -> Result<()> + Send + Sync>;

/// One rule: pattern over instance-type strings + ordered modifiers.
pub struct MeshRule {
    /// The glob-flavored source pattern (e.g. `"tpu-v5e-256-*"`).
    pub pattern: String,
    regex: Regex,
    /// Config modifiers applied, in order, when the pattern matches.
    pub modifiers: ModifierList,
    /// Optional dynamic body, run after `modifiers` with the full
    /// instance-type string (see [`MeshRule::dynamic`]).
    dynamic: Option<DynamicRule>,
}

impl MeshRule {
    /// Compile a rule from a glob-flavored pattern (as in the paper:
    /// `"tpu-v5e-256-*"` — `*` matches anything, everything else is
    /// literal) and its ordered modifiers.
    pub fn new(pattern: &str, modifiers: Vec<Box<dyn ConfigModifier>>) -> Result<Self> {
        // Glob-flavored pattern as in the paper ("tpu-v5e-256-*"): translate
        // `*` to `.*` and anchor.
        let regex = Regex::new(&glob_to_regex(pattern))?;
        Ok(MeshRule {
            pattern: pattern.to_string(),
            regex,
            modifiers: ModifierList(modifiers),
            dynamic: None,
        })
    }

    /// Compile a rule whose body is computed from the matched instance
    /// type (e.g. the auto-sharding planner deriving a mesh from the
    /// chip family and count encoded in `planner-gpu-H100-4096`).
    /// Static rules can't express this: the right-hand side depends on
    /// what the wildcard matched.
    pub fn dynamic(
        pattern: &str,
        body: impl Fn(&str, &mut ConfigNode) -> Result<()> + Send + Sync + 'static,
    ) -> Result<Self> {
        let mut rule = MeshRule::new(pattern, vec![])?;
        rule.dynamic = Some(Box::new(body));
        Ok(rule)
    }

    /// Whether this rule's pattern matches `instance_type`.
    pub fn matches(&self, instance_type: &str) -> bool {
        self.regex.is_match(instance_type)
    }
}

fn glob_to_regex(glob: &str) -> String {
    let mut out = String::from("^");
    for c in glob.chars() {
        match c {
            '*' => out.push_str(".*"),
            c if "\\.+()[]{}^$|?".contains(c) => {
                out.push('\\');
                out.push(c);
            }
            c => out.push(c),
        }
    }
    out.push('$');
    out
}

/// Ordered rule table; first match wins (like the paper's list form).
pub struct MeshRules {
    /// Rules in priority order.
    pub rules: Vec<MeshRule>,
}

impl MeshRules {
    /// Build a table from rules in priority order.
    pub fn new(rules: Vec<MeshRule>) -> Self {
        MeshRules { rules }
    }

    /// Find the first rule matching `instance_type`.
    pub fn find(&self, instance_type: &str) -> Option<&MeshRule> {
        self.rules.iter().find(|r| r.matches(instance_type))
    }

    /// Apply the first matching rule's modifiers to `cfg`. Returns the
    /// matched pattern, or None if nothing matched (config left unchanged
    /// — XLA defaults, as the paper notes, are often reasonable).
    ///
    /// ```
    /// use axlearn::config::mesh_rules::paper_appendix_a_rules;
    /// use axlearn::config::registry::trainer_for_preset;
    ///
    /// let rules = paper_appendix_a_rules();
    /// let mut cfg = trainer_for_preset("small").unwrap();
    ///
    /// // Launching on H100s rewrites the mesh to fsdp×model + FP8:
    /// let matched = rules.apply("gpu-H100-64", &mut cfg).unwrap();
    /// assert_eq!(matched.as_deref(), Some("gpu-H100-*"));
    /// assert_eq!(cfg.get_str("quantization").unwrap(), "fp8");
    /// assert_eq!(
    ///     cfg.get_str_list("mesh_axis_names").unwrap(),
    ///     vec!["fsdp".to_string(), "model".to_string()]
    /// );
    ///
    /// // An unknown platform matches nothing and changes nothing:
    /// let mut other = trainer_for_preset("small").unwrap();
    /// assert!(rules.apply("cpu-local", &mut other).unwrap().is_none());
    /// ```
    pub fn apply(&self, instance_type: &str, cfg: &mut ConfigNode) -> Result<Option<String>> {
        match self.find(instance_type) {
            Some(rule) => {
                rule.modifiers.apply(cfg)?;
                if let Some(body) = &rule.dynamic {
                    body(instance_type, cfg)?;
                }
                Ok(Some(rule.pattern.clone()))
            }
            None => Ok(None),
        }
    }
}

/// The paper's Appendix-A rule table, expressed 1:1 in Rust.  Used by the
/// `heterogeneous` example and the Table-3 composer plans.
pub fn paper_appendix_a_rules() -> MeshRules {
    use super::modifier::*;
    use super::node::Value;
    MeshRules::new(vec![
        MeshRule::new(
            "tpu-v5e-256-*",
            vec![
                Box::new(MeshShapeModifier::new(&[-1, 256], &["data", "fsdp"])),
                Box::new(RematSpecModifier::at("offload_dots", "model.decoder.layer")),
                Box::new(QuantizationModifier::int8()),
            ],
        )
        .unwrap(),
        // MoE v5e pods (the "-moe" instance flavor): FSDP within the
        // slice with a 16-way expert axis, so the expert bank shards and
        // tokens dispatch over AllToAll (docs/moe.md walks this preset).
        // The generous capacity factor reflects v5e's cheap intra-slice
        // all-to-alls: headroom is cheaper than drops.
        MeshRule::new(
            "tpu-v5e-moe-*",
            vec![
                Box::new(MeshShapeModifier::new(
                    &[-1, 16, 16],
                    &["data", "fsdp", "expert"],
                )),
                Box::new(SetFieldModifier::new("", "capacity_factor", Value::Float(2.0))),
                Box::new(RematSpecModifier::at("offload_dots", "model.decoder.layer")),
                Box::new(QuantizationModifier::int8()),
            ],
        )
        .unwrap(),
        // Pipelined H100 pods (the "-pp" instance flavor): FSDP within
        // the node, 4 pipeline stages across nodes with a 1F1B
        // microbatch schedule — listed before the generic H100 rule so
        // first-match-wins picks the more specific pattern.
        MeshRule::new(
            "gpu-H100-pp-*",
            vec![
                Box::new(MeshShapeModifier::new(
                    &[-1, 4, 8],
                    &["fsdp", "pipeline", "model"],
                )),
                Box::new(SetFieldModifier::new("", "microbatches", Value::Int(16))),
                Box::new(SetFieldModifier::new(
                    "",
                    "pipeline_schedule",
                    Value::Str("1f1b".into()),
                )),
                Box::new(RematSpecModifier::at("save_qkvo", "model.decoder.layer")),
                Box::new(QuantizationModifier::fp8(128)),
            ],
        )
        .unwrap(),
        MeshRule::new(
            "gpu-H100-*",
            vec![
                Box::new(MeshShapeModifier::new(&[-1, 8], &["fsdp", "model"])),
                Box::new(RematSpecModifier::at("save_qkvo", "model.decoder.layer")),
                Box::new(QuantizationModifier::fp8(128)),
            ],
        )
        .unwrap(),
        // Additions for the full Table-3 matrix:
        MeshRule::new(
            "tpu-v5p-*",
            vec![
                Box::new(MeshShapeModifier::new(&[-1, 16], &["data", "fsdp"])),
                Box::new(RematSpecModifier::at("save_linear", "model.decoder.layer")),
            ],
        )
        .unwrap(),
        MeshRule::new(
            "trn2-*",
            vec![
                Box::new(MeshShapeModifier::new(&[-1, 16], &["data", "fsdp"])),
                Box::new(RematSpecModifier::at("save_qkvo", "model.decoder.layer")),
                Box::new(KernelModifier::new("nki")),
            ],
        )
        .unwrap(),
        // Serving presets live in the same rule table as the trainer
        // rules: a `serve-tp4-ep2-p2-d4-s1` instance string rewrites a
        // `ServeSpec` config node's pool membership and shard layout
        // (crate::serving::spec parses the string; the spec's lowering
        // then derives the schedule).
        MeshRule::dynamic("serve-*", |inst, cfg| {
            let spec = crate::serving::spec::ServeSpec::parse_rule(inst)?;
            cfg.set("tp", Value::Int(spec.tp as i64))?;
            cfg.set("ep", Value::Int(spec.ep as i64))?;
            cfg.set("prefill_replicas", Value::Int(spec.prefill_replicas as i64))?;
            cfg.set("decode_replicas", Value::Int(spec.decode_replicas as i64))?;
            cfg.set("spares", Value::Int(spec.spares as i64))?;
            cfg.set("num_experts", Value::Int(spec.num_experts as i64))?;
            cfg.set("active_experts", Value::Int(spec.active_experts as i64))?;
            Ok(())
        })
        .unwrap(),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registry::trainer_for_preset;

    #[test]
    fn glob_translation() {
        assert_eq!(glob_to_regex("tpu-v5e-256-*"), "^tpu-v5e-256-.*$");
        assert!(Regex::new(&glob_to_regex("a*b")).unwrap().is_match("aXYZb"));
        assert!(!Regex::new(&glob_to_regex("a*b")).unwrap().is_match("aXYZc"));
    }

    #[test]
    fn first_match_wins() {
        let rules = MeshRules::new(vec![
            MeshRule::new("tpu-*", vec![]).unwrap(),
            MeshRule::new("tpu-v5e-*", vec![]).unwrap(),
        ]);
        assert_eq!(rules.find("tpu-v5e-256-4").unwrap().pattern, "tpu-*");
    }

    #[test]
    fn dynamic_rule_sees_the_matched_instance_string() {
        use super::super::node::Value;
        let rules = MeshRules::new(vec![MeshRule::dynamic("planner-*", |inst, cfg| {
            let chips: i64 = inst.rsplit('-').next().unwrap().parse()?;
            cfg.set("max_steps", Value::Int(chips))?;
            Ok(())
        })
        .unwrap()]);
        let mut t = trainer_for_preset("tiny").unwrap();
        let matched = rules.apply("planner-gpu-H100-4096", &mut t).unwrap();
        assert_eq!(matched.as_deref(), Some("planner-*"));
        assert_eq!(t.get_int("max_steps").unwrap(), 4096);
    }

    #[test]
    fn serve_rule_rewrites_the_spec_from_the_instance_string() {
        use crate::config::registry::default_config;
        let rules = paper_appendix_a_rules();
        let mut s = default_config("ServeSpec").unwrap();
        let matched = rules.apply("serve-tp4-ep2-p2-d4-s1", &mut s).unwrap();
        assert_eq!(matched.as_deref(), Some("serve-*"));
        assert_eq!(s.get_int("tp").unwrap(), 4);
        assert_eq!(s.get_int("ep").unwrap(), 2);
        assert_eq!(s.get_int("prefill_replicas").unwrap(), 2);
        assert_eq!(s.get_int("decode_replicas").unwrap(), 4);
        assert_eq!(s.get_int("spares").unwrap(), 1);
        assert_eq!(s.get_int("num_experts").unwrap(), 8);
        // the rewritten node round-trips into a lowerable spec
        let spec = crate::serving::ServeSpec::from_config(&s).unwrap();
        assert_eq!(spec.name(), "serve-tp4-ep2-p2-d4-s1");
        assert!(spec.lower().unwrap().kv_handoff_bytes > 0.0);
        // malformed serve instances fail loudly, not silently
        let mut bad = default_config("ServeSpec").unwrap();
        assert!(rules.apply("serve-q4", &mut bad).is_err());
    }

    #[test]
    fn no_match_leaves_config_unchanged() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("tiny").unwrap();
        let before = t.clone();
        let matched = rules.apply("cpu-local", &mut t).unwrap();
        assert!(matched.is_none());
        assert_eq!(t, before);
    }

    #[test]
    fn appendix_a_tpu_v5e_rule() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("small").unwrap();
        let matched = rules.apply("tpu-v5e-256-8", &mut t).unwrap();
        assert_eq!(matched.as_deref(), Some("tpu-v5e-256-*"));
        assert_eq!(t.get_int_list("mesh_shape").unwrap(), vec![-1, 256]);
        assert_eq!(t.get_str("quantization").unwrap(), "int8");
        assert_eq!(
            t.at_path("model.decoder.layer").unwrap().get_str("remat_spec").unwrap(),
            "offload_dots"
        );
    }

    #[test]
    fn appendix_a_h100_rule() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("small").unwrap();
        rules.apply("gpu-H100-32", &mut t).unwrap();
        assert_eq!(t.get_str_list("mesh_axis_names").unwrap(), vec!["fsdp", "model"]);
        assert_eq!(t.get_str("quantization").unwrap(), "fp8");
        assert_eq!(
            t.at_path("model.decoder.layer").unwrap().get_str("remat_spec").unwrap(),
            "save_qkvo"
        );
    }

    #[test]
    fn h100_pp_rule_adds_a_pipeline_axis() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("small").unwrap();
        let matched = rules.apply("gpu-H100-pp-64", &mut t).unwrap();
        assert_eq!(matched.as_deref(), Some("gpu-H100-pp-*"));
        assert_eq!(
            t.get_str_list("mesh_axis_names").unwrap(),
            vec!["fsdp", "pipeline", "model"]
        );
        assert_eq!(t.get_int_list("mesh_shape").unwrap(), vec![-1, 4, 8]);
        assert_eq!(t.get_int("microbatches").unwrap(), 16);
        assert_eq!(t.get_str("pipeline_schedule").unwrap(), "1f1b");
        // the more specific pattern must not shadow plain H100 strings
        let mut plain = trainer_for_preset("small").unwrap();
        assert_eq!(
            rules.apply("gpu-H100-64", &mut plain).unwrap().as_deref(),
            Some("gpu-H100-*")
        );
        assert_eq!(plain.get_int("microbatches").unwrap(), 1);
    }

    #[test]
    fn v5e_moe_rule_adds_an_expert_axis() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("small").unwrap();
        let matched = rules.apply("tpu-v5e-moe-512", &mut t).unwrap();
        assert_eq!(matched.as_deref(), Some("tpu-v5e-moe-*"));
        assert_eq!(
            t.get_str_list("mesh_axis_names").unwrap(),
            vec!["data", "fsdp", "expert"]
        );
        assert_eq!(t.get_int_list("mesh_shape").unwrap(), vec![-1, 16, 16]);
        assert_eq!(t.get_float("capacity_factor").unwrap(), 2.0);
        assert_eq!(t.get_str("quantization").unwrap(), "int8");
        // the MoE flavor must not shadow plain v5e instance strings
        let mut plain = trainer_for_preset("small").unwrap();
        assert_eq!(
            rules.apply("tpu-v5e-256-8", &mut plain).unwrap().as_deref(),
            Some("tpu-v5e-256-*")
        );
        assert!(!plain
            .get_str_list("mesh_axis_names")
            .unwrap()
            .contains(&"expert".to_string()));
    }

    #[test]
    fn same_config_two_targets_differ_only_by_rules() {
        // The heterogeneity claim: ONE experiment config, two platforms.
        let rules = paper_appendix_a_rules();
        let base = trainer_for_preset("small").unwrap();
        let mut tpu = base.clone();
        let mut gpu = base.clone();
        rules.apply("tpu-v5e-256-1", &mut tpu).unwrap();
        rules.apply("gpu-H100-64", &mut gpu).unwrap();
        // model architecture identical
        assert_eq!(tpu.at_path("model").unwrap().child("decoder").unwrap().get_int("model_dim").unwrap(),
                   gpu.at_path("model").unwrap().child("decoder").unwrap().get_int("model_dim").unwrap());
        // runtime strategy differs
        assert_ne!(tpu.get_str("quantization").unwrap(), gpu.get_str("quantization").unwrap());
    }

    #[test]
    fn trn2_rule_swaps_kernel_backend() {
        let rules = paper_appendix_a_rules();
        let mut t = trainer_for_preset("small").unwrap();
        rules.apply("trn2-16xlarge", &mut t).unwrap();
        let attn = t.at_path("model.decoder.layer.self_attention").unwrap();
        assert_eq!(attn.klass, "FlashAttentionLayer");
        assert_eq!(attn.get_str("backend").unwrap(), "nki");
    }
}
