//! The hierarchical, strictly-encapsulated configuration system
//! (paper §4.1) — AXLearn's core contribution.
//!
//! Design notes, mirroring the paper:
//!
//! * **Hierarchical composition, not flattening.** A config is a tree of
//!   [`ConfigNode`]s; a parent node holds child *configs*, never child
//!   hyper-parameters. `TransformerLayer`'s config does not know RoPE's
//!   `theta` — that is encapsulated inside the `pos_emb` child.
//! * **Partial specification.** Fields may be unset ([`Value::Null`]) and
//!   filled by the parent at instantiation time (e.g. `input_dim`
//!   propagation), or defined as a deferred function of another field
//!   (`Value::ScaledDim` — the `scaled_hidden_dim` idiom).
//! * **Traversal-based re-parameterization.** [`traverse::replace_config`]
//!   implements the 10-line MoE/RoPE swap of Figure 1: O(1)
//!   LoC-complexity because no ancestor interface mentions the feature.
//! * **Config modifiers & mesh rules** ([`modifier`], [`mesh_rules`]):
//!   per-target-platform rewrites (Appendix A), applied by regex match on
//!   the instance type.
//! * **Golden serialization** ([`golden`]): canonical human-readable dumps
//!   committed next to code, the paper's §7.3 testing practice.

pub mod golden;
pub mod mesh_rules;
pub mod modifier;
pub mod node;
pub mod registry;
pub mod traverse;

pub use golden::{config_diff, to_golden_lines};
pub use mesh_rules::{MeshRule, MeshRules};
pub use modifier::{
    ConfigModifier, KernelModifier, MeshShapeModifier, ModifierList, QuantizationModifier,
    RematSpecModifier, SetFieldModifier,
};
pub use node::{ConfigError, ConfigNode, Value};
pub use registry::{default_config, register_defaults};
pub use traverse::{find_all, replace_config, visit, visit_mut};
