//! Config-tree traversal: the mechanism behind the paper's O(1)
//! LoC-complexity claim (§2.1, §4.1).
//!
//! `replace_config` is the Rust twin of the 10-line python snippet used to
//! apply MoE to 1,000+ experiment configs: it rewrites every sub-config of
//! a target class without any ancestor module knowing.

use super::node::{ConfigNode, Value};

/// Pre-order immutable visit. `f` receives (path, node).
pub fn visit<F: FnMut(&str, &ConfigNode)>(root: &ConfigNode, f: &mut F) {
    fn go<F: FnMut(&str, &ConfigNode)>(path: &str, node: &ConfigNode, f: &mut F) {
        f(path, node);
        for (name, child) in node.children() {
            let child_path = if path.is_empty() {
                name.clone()
            } else {
                format!("{path}.{name}")
            };
            go(&child_path, child, f);
        }
    }
    go("", root, f);
}

/// Pre-order mutable visit.
pub fn visit_mut<F: FnMut(&str, &mut ConfigNode)>(root: &mut ConfigNode, f: &mut F) {
    fn go<F: FnMut(&str, &mut ConfigNode)>(path: String, node: &mut ConfigNode, f: &mut F) {
        f(&path, node);
        let prefix = if path.is_empty() { String::new() } else { format!("{path}.") };
        for (name, value) in node.fields_iter_mut() {
            match value {
                Value::Config(c) => go(format!("{prefix}{name}"), c, f),
                Value::ConfigList(cs) => {
                    for (i, c) in cs.iter_mut().enumerate() {
                        go(format!("{prefix}{name}[{i}]"), c, f);
                    }
                }
                _ => {}
            }
        }
    }
    go(String::new(), root, f);
}

/// Paths of every sub-config whose klass equals `target`.
pub fn find_all(root: &ConfigNode, target: &str) -> Vec<String> {
    let mut out = Vec::new();
    visit(root, &mut |path, node| {
        if node.klass == target {
            out.push(path.to_string());
        }
    });
    out
}

/// Recursively replace any sub-config whose klass is `target` with the
/// config produced by `factory(old)`. Returns the number of replacements.
///
/// This is Figure 1's drop-in MoE swap: so long as the replacement honors
/// the same input/output interface, *no other module changes*.
pub fn replace_config<F>(root: &mut ConfigNode, target: &str, factory: &F) -> usize
where
    F: Fn(&ConfigNode) -> ConfigNode,
{
    let mut count = 0;
    // Root itself (callers normally target interior nodes, but be total).
    if root.klass == target {
        *root = factory(root);
        return 1;
    }
    fn go<F: Fn(&ConfigNode) -> ConfigNode>(node: &mut ConfigNode, target: &str, factory: &F, count: &mut usize) {
        for (_name, value) in node.fields_iter_mut() {
            match value {
                Value::Config(c) => {
                    if c.klass == target {
                        *c = factory(c);
                        *count += 1;
                    } else {
                        go(c, target, factory, count);
                    }
                }
                Value::ConfigList(cs) => {
                    for c in cs.iter_mut() {
                        if c.klass == target {
                            *c = factory(c);
                            *count += 1;
                        } else {
                            go(c, target, factory, count);
                        }
                    }
                }
                _ => {}
            }
        }
    }
    go(root, target, factory, &mut count);
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registry;
    use crate::util::rng::Rng;

    fn model() -> ConfigNode {
        registry::default_config("CausalLM").unwrap()
    }

    #[test]
    fn visit_covers_all_nodes() {
        let root = model();
        let mut paths = Vec::new();
        visit(&root, &mut |p, _| paths.push(p.to_string()));
        assert!(paths.contains(&"".to_string()));
        assert!(paths.iter().any(|p| p.contains("feed_forward")));
        assert!(paths.iter().any(|p| p.contains("pos_emb")));
    }

    #[test]
    fn find_all_locates_ffn() {
        let root = model();
        let found = find_all(&root, "FeedForward");
        assert_eq!(found.len(), 1);
        assert!(found[0].ends_with("feed_forward"));
    }

    #[test]
    fn replace_ffn_with_moe_is_ten_lines() {
        // The paper's snippet, verbatim shape: traverse + swap. Nothing
        // else in the tree changes.
        let mut root = model();
        let before_attn = root.at_path("decoder.layer.self_attention").unwrap().clone();
        let n = replace_config(&mut root, "FeedForward", &|old| {
            registry::default_config("MoE").unwrap()
                .with("input_dim", old.get("input_dim").unwrap().clone())
                .with("num_experts", Value::Int(8))
                .with("top_k", Value::Int(2))
        });
        assert_eq!(n, 1);
        assert_eq!(root.at_path("decoder.layer.feed_forward").unwrap().klass, "MoE");
        // strict encapsulation: attention untouched
        assert_eq!(
            root.at_path("decoder.layer.self_attention").unwrap(),
            &before_attn
        );
    }

    #[test]
    fn replace_rope_with_nope() {
        let mut root = model();
        let n = replace_config(&mut root, "RotaryEmbedding", &|_| {
            registry::default_config("NoPositionalEmbedding").unwrap()
        });
        assert_eq!(n, 1);
        assert_eq!(
            root.at_path("decoder.layer.self_attention.pos_emb").unwrap().klass,
            "NoPositionalEmbedding"
        );
    }

    #[test]
    fn replace_counts_multiple_targets() {
        let mut root = ConfigNode::new("Stack").field(
            "layers",
            Value::ConfigList(vec![
                ConfigNode::new("FeedForward").field("input_dim", Value::Int(1)),
                ConfigNode::new("FeedForward").field("input_dim", Value::Int(2)),
                ConfigNode::new("Attention"),
            ]),
        );
        let n = replace_config(&mut root, "FeedForward", &|old| {
            ConfigNode::new("MoE").field("input_dim", old.get("input_dim").unwrap().clone())
        });
        assert_eq!(n, 2);
        assert_eq!(root.at_path("layers[0]").unwrap().klass, "MoE");
        assert_eq!(root.at_path("layers[1]").unwrap().get_int("input_dim").unwrap(), 2);
        assert_eq!(root.at_path("layers[2]").unwrap().klass, "Attention");
    }

    #[test]
    fn replace_preserves_tree_shape_property() {
        // Property (hand-rolled): replacing X->X' leaves every non-target
        // path identical, for randomized trees.
        let mut rng = Rng::new(99);
        for _ in 0..25 {
            let mut root = random_tree(&mut rng, 3);
            let before: Vec<String> = {
                let mut v = Vec::new();
                visit(&root, &mut |p, n| v.push(format!("{p}:{}", n.klass)));
                v
            };
            let n_targets = before.iter().filter(|s| s.ends_with(":Target")).count();
            let n = replace_config(&mut root, "Target", &|_| ConfigNode::new("Replaced"));
            assert_eq!(n, n_targets);
            let mut after = Vec::new();
            visit(&root, &mut |p, n| after.push(format!("{p}:{}", n.klass)));
            assert_eq!(before.len(), after.len());
            for (b, a) in before.iter().zip(&after) {
                if b.ends_with(":Target") {
                    assert!(a.ends_with(":Replaced"), "{b} -> {a}");
                } else {
                    assert_eq!(b, a);
                }
            }
        }
    }

    fn random_tree(rng: &mut Rng, depth: usize) -> ConfigNode {
        // "Target" nodes only at the leaves so the replacement (which has
        // no children) preserves the overall path set.
        let klass = if depth == 0 {
            *rng.choose(&["A", "Target", "Target", "C"])
        } else {
            *rng.choose(&["A", "B", "C"])
        };
        let mut node = ConfigNode::new(klass).field("x", Value::Int(rng.gen_range(0, 100) as i64));
        if depth > 0 {
            let n_children = rng.gen_range(1, 4);
            for i in 0..n_children {
                node = node.field(&format!("c{i}"), Value::Config(random_tree(rng, depth - 1)));
            }
        }
        node
    }
}
