//! Golden-configuration serialization (paper §7.3).
//!
//! "Key training configs are serialized into human readable format and
//! committed along with code changes" — changes produce reviewable diffs,
//! trigger code-owner review, and give experiments a traceable history.
//!
//! The format is line-oriented and canonical (sorted fields), so identical
//! configs always serialize identically and `diff` output is meaningful.
//! `rust/tests/golden_configs.rs` compares the presets against the files
//! committed under `rust/golden/`.

use super::node::{ConfigNode, Value};

/// Serialize a config tree to canonical golden lines.
pub fn to_golden_lines(cfg: &ConfigNode) -> Vec<String> {
    let mut lines = Vec::new();
    emit(cfg, "root", &mut lines);
    lines
}

fn emit(node: &ConfigNode, path: &str, lines: &mut Vec<String>) {
    lines.push(format!("{path}: {}", node.klass));
    for (name, value) in node.fields_iter() {
        let field_path = format!("{path}.{name}");
        match value {
            Value::Config(c) => emit(c, &field_path, lines),
            Value::ConfigList(cs) => {
                for (i, c) in cs.iter().enumerate() {
                    emit(c, &format!("{field_path}[{i}]"), lines);
                }
            }
            other => lines.push(format!("{field_path} = {other}")),
        }
    }
}

/// Serialize to a single string (with trailing newline, as committed).
pub fn to_golden_string(cfg: &ConfigNode) -> String {
    let mut s = to_golden_lines(cfg).join("\n");
    s.push('\n');
    s
}

/// Line-level diff between two golden serializations: returns
/// (only_in_a, only_in_b) preserving order.  This is what a reviewer sees
/// when an experiment config changes.
pub fn config_diff(a: &ConfigNode, b: &ConfigNode) -> (Vec<String>, Vec<String>) {
    let la = to_golden_lines(a);
    let lb = to_golden_lines(b);
    let sa: std::collections::HashSet<&String> = la.iter().collect();
    let sb: std::collections::HashSet<&String> = lb.iter().collect();
    let only_a = la.iter().filter(|l| !sb.contains(*l)).cloned().collect();
    let only_b = lb.iter().filter(|l| !sa.contains(*l)).cloned().collect();
    (only_a, only_b)
}

/// Parse golden lines back into (path, repr) pairs for structural checks.
/// (Full deserialization is intentionally out of scope: goldens are a
/// review artifact, the source of truth stays in code — as in the paper.)
pub fn parse_golden(text: &str) -> Vec<(String, String)> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| match l.split_once(" = ") {
            Some((path, v)) => (path.trim().to_string(), v.trim().to_string()),
            None => match l.split_once(": ") {
                Some((path, klass)) => (path.trim().to_string(), format!("<{}>", klass.trim())),
                None => (l.trim().to_string(), String::new()),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::modifier::{ConfigModifier, QuantizationModifier};
    use crate::config::registry::{default_config, trainer_for_preset};
    use crate::config::traverse::replace_config;

    #[test]
    fn serialization_is_deterministic() {
        let a = trainer_for_preset("small").unwrap();
        let b = trainer_for_preset("small").unwrap();
        assert_eq!(to_golden_string(&a), to_golden_string(&b));
    }

    #[test]
    fn serialization_covers_nested_fields() {
        let s = to_golden_string(&trainer_for_preset("tiny").unwrap());
        assert!(s.contains("root: Trainer"));
        assert!(s.contains("root.model.decoder.layer.self_attention: AttentionLayer"));
        assert!(s.contains("root.model.decoder.layer.self_attention.pos_emb.theta = 10000"));
    }

    #[test]
    fn clone_roundtrip_identical() {
        let a = trainer_for_preset("base100m").unwrap();
        assert_eq!(to_golden_string(&a), to_golden_string(&a.clone()));
    }

    #[test]
    fn diff_is_empty_for_identical() {
        let a = trainer_for_preset("small").unwrap();
        let (oa, ob) = config_diff(&a, &a.clone());
        assert!(oa.is_empty() && ob.is_empty());
    }

    #[test]
    fn diff_localizes_a_change() {
        // The review story: an MoE swap shows up ONLY as feed_forward lines.
        let a = trainer_for_preset("small").unwrap();
        let mut b = a.clone();
        replace_config(&mut b, "FeedForward", &|old| {
            default_config("MoE").unwrap().with("input_dim", old.get("input_dim").unwrap().clone())
        });
        let (only_a, only_b) = config_diff(&a, &b);
        assert!(!only_a.is_empty() && !only_b.is_empty());
        for line in only_a.iter().chain(only_b.iter()) {
            assert!(
                line.contains("feed_forward"),
                "diff leaked outside feed_forward: {line}"
            );
        }
    }

    #[test]
    fn diff_catches_quantization_change() {
        let a = trainer_for_preset("small").unwrap();
        let mut b = a.clone();
        QuantizationModifier::int8().apply(&mut b).unwrap();
        let (_, only_b) = config_diff(&a, &b);
        assert_eq!(only_b, vec!["root.quantization = \"int8\"".to_string()]);
    }

    #[test]
    fn parse_golden_roundtrip_paths() {
        let s = to_golden_string(&trainer_for_preset("tiny").unwrap());
        let entries = parse_golden(&s);
        assert!(entries.iter().any(|(p, v)| p == "root" && v == "<Trainer>"));
        assert!(entries.iter().any(|(p, _)| p.ends_with(".learning_rate")));
    }
}
