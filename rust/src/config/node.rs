//! Config tree nodes and values.

use std::collections::BTreeMap;
use std::fmt;

/// Errors raised by config operations.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("{klass}: unknown field {field:?} (known: {known:?})")]
    UnknownField {
        klass: String,
        field: String,
        known: Vec<String>,
    },
    #[error("{klass}.{field}: required field is unset")]
    RequiredUnset { klass: String, field: String },
    #[error("{klass}.{field}: expected {expected}, got {got}")]
    TypeMismatch {
        klass: String,
        field: String,
        expected: &'static str,
        got: String,
    },
    #[error("no config node at path {0:?}")]
    BadPath(String),
    #[error("unknown class {klass:?} (registered: {registered:?})")]
    UnknownClass {
        klass: String,
        registered: Vec<String>,
    },
    #[error("unknown preset {preset:?} (known: {known:?})")]
    UnknownPreset { preset: String, known: Vec<String> },
}

/// A config field value.
///
/// `Config`/`ConfigList` make the tree hierarchical; `ScaledDim` is the
/// deferred-dimension idiom (`scaled_hidden_dim(scale=8/3)` in the paper):
/// it resolves to `round(multiplier * reference_dim)` when the parent
/// propagates the reference dim.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    IntList(Vec<i64>),
    StrList(Vec<String>),
    Config(ConfigNode),
    ConfigList(Vec<ConfigNode>),
    /// Deferred dimension: multiplier on a not-yet-known reference dim.
    ScaledDim(f64),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Str(_) => "str",
            Value::IntList(_) => "int_list",
            Value::StrList(_) => "str_list",
            Value::Config(_) => "config",
            Value::ConfigList(_) => "config_list",
            Value::ScaledDim(_) => "scaled_dim",
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "None"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::IntList(xs) => write!(f, "{xs:?}"),
            Value::StrList(xs) => write!(f, "{xs:?}"),
            Value::Config(c) => write!(f, "<{}>", c.klass),
            Value::ConfigList(cs) => write!(f, "<{} configs>", cs.len()),
            Value::ScaledDim(m) => write!(f, "scaled_dim({m})"),
        }
    }
}

macro_rules! typed_getter {
    ($get:ident, $variant:ident, $ty:ty, $expected:expr) => {
        pub fn $get(&self, field: &str) -> Result<$ty, ConfigError> {
            match self.get(field)? {
                Value::$variant(x) => Ok(x.clone()),
                other => Err(ConfigError::TypeMismatch {
                    klass: self.klass.clone(),
                    field: field.to_string(),
                    expected: $expected,
                    got: other.type_name().to_string(),
                }),
            }
        }
    };
}

/// A node in the config tree: the class it configures plus its fields.
///
/// Field order is canonical (BTreeMap) so golden serialization is stable.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigNode {
    pub klass: String,
    fields: BTreeMap<String, Value>,
}

impl ConfigNode {
    pub fn new(klass: &str) -> Self {
        ConfigNode {
            klass: klass.to_string(),
            fields: BTreeMap::new(),
        }
    }

    /// Declare a field with its default value. Builder-style, used by
    /// `default_config` constructors in [`super::registry`].
    pub fn field(mut self, name: &str, value: Value) -> Self {
        self.fields.insert(name.to_string(), value);
        self
    }

    pub fn field_names(&self) -> Vec<String> {
        self.fields.keys().cloned().collect()
    }

    pub fn has_field(&self, name: &str) -> bool {
        self.fields.contains_key(name)
    }

    pub fn get(&self, field: &str) -> Result<&Value, ConfigError> {
        self.fields.get(field).ok_or_else(|| ConfigError::UnknownField {
            klass: self.klass.clone(),
            field: field.to_string(),
            known: self.field_names(),
        })
    }

    /// Strict setter: the field must already exist (declared by
    /// `default_config`). This is what makes encapsulation *strict*: you
    /// cannot graft RoPE fields onto an attention config from outside.
    pub fn set(&mut self, field: &str, value: Value) -> Result<&mut Self, ConfigError> {
        if !self.fields.contains_key(field) {
            return Err(ConfigError::UnknownField {
                klass: self.klass.clone(),
                field: field.to_string(),
                known: self.field_names(),
            });
        }
        self.fields.insert(field.to_string(), value);
        Ok(self)
    }

    /// Chainable setter that panics on unknown fields — for preset
    /// construction where the field set is static.
    pub fn with(mut self, field: &str, value: Value) -> Self {
        self.set(field, value)
            .unwrap_or_else(|e| panic!("{e}"));
        self
    }

    typed_getter!(get_bool, Bool, bool, "bool");
    typed_getter!(get_int, Int, i64, "int");
    typed_getter!(get_float, Float, f64, "float");
    typed_getter!(get_str, Str, String, "str");
    typed_getter!(get_int_list, IntList, Vec<i64>, "int_list");
    typed_getter!(get_str_list, StrList, Vec<String>, "str_list");

    /// Float getter that also accepts ints (mesh sizes etc.).
    pub fn get_num(&self, field: &str) -> Result<f64, ConfigError> {
        match self.get(field)? {
            Value::Float(x) => Ok(*x),
            Value::Int(i) => Ok(*i as f64),
            other => Err(ConfigError::TypeMismatch {
                klass: self.klass.clone(),
                field: field.to_string(),
                expected: "number",
                got: other.type_name().to_string(),
            }),
        }
    }

    pub fn child(&self, field: &str) -> Result<&ConfigNode, ConfigError> {
        match self.get(field)? {
            Value::Config(c) => Ok(c),
            other => Err(ConfigError::TypeMismatch {
                klass: self.klass.clone(),
                field: field.to_string(),
                expected: "config",
                got: other.type_name().to_string(),
            }),
        }
    }

    pub fn child_mut(&mut self, field: &str) -> Result<&mut ConfigNode, ConfigError> {
        let klass = self.klass.clone();
        let known = self.field_names();
        match self.fields.get_mut(field) {
            Some(Value::Config(c)) => Ok(c),
            Some(other) => Err(ConfigError::TypeMismatch {
                klass,
                field: field.to_string(),
                expected: "config",
                got: other.type_name().to_string(),
            }),
            None => Err(ConfigError::UnknownField {
                klass,
                field: field.to_string(),
                known,
            }),
        }
    }

    /// Required-field check used at instantiation/materialization time.
    pub fn require(&self, field: &str) -> Result<&Value, ConfigError> {
        let v = self.get(field)?;
        if v.is_null() {
            return Err(ConfigError::RequiredUnset {
                klass: self.klass.clone(),
                field: field.to_string(),
            });
        }
        Ok(v)
    }

    /// Navigate a dotted path (`"decoder.layer.self_attention"`); list
    /// elements addressed as `layers[3]`.
    pub fn at_path(&self, path: &str) -> Result<&ConfigNode, ConfigError> {
        let mut cur = self;
        if path.is_empty() {
            return Ok(cur);
        }
        for seg in path.split('.') {
            let (name, idx) = parse_segment(seg).ok_or_else(|| ConfigError::BadPath(path.to_string()))?;
            let v = cur.get(name).map_err(|_| ConfigError::BadPath(path.to_string()))?;
            cur = match (v, idx) {
                (Value::Config(c), None) => c,
                (Value::ConfigList(cs), Some(i)) if i < cs.len() => &cs[i],
                _ => return Err(ConfigError::BadPath(path.to_string())),
            };
        }
        Ok(cur)
    }

    /// Mutable path navigation.
    pub fn at_path_mut(&mut self, path: &str) -> Result<&mut ConfigNode, ConfigError> {
        let mut cur = self;
        if path.is_empty() {
            return Ok(cur);
        }
        for seg in path.split('.') {
            let (name, idx) = parse_segment(seg).ok_or_else(|| ConfigError::BadPath(path.to_string()))?;
            let v = cur.fields.get_mut(name).ok_or_else(|| ConfigError::BadPath(path.to_string()))?;
            cur = match (v, idx) {
                (Value::Config(c), None) => c,
                (Value::ConfigList(cs), Some(i)) if i < cs.len() => &mut cs[i],
                _ => return Err(ConfigError::BadPath(path.to_string())),
            };
        }
        Ok(cur)
    }

    /// Iterate child configs (name, node), including list elements as
    /// `name[i]`.
    pub fn children(&self) -> Vec<(String, &ConfigNode)> {
        let mut out = Vec::new();
        for (name, v) in &self.fields {
            match v {
                Value::Config(c) => out.push((name.clone(), c)),
                Value::ConfigList(cs) => {
                    for (i, c) in cs.iter().enumerate() {
                        out.push((format!("{name}[{i}]"), c));
                    }
                }
                _ => {}
            }
        }
        out
    }

    pub(crate) fn fields_iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.fields.iter()
    }

    pub(crate) fn fields_iter_mut(&mut self) -> impl Iterator<Item = (&String, &mut Value)> {
        self.fields.iter_mut()
    }

    /// Resolve a `ScaledDim` field against a reference dim (parent
    /// propagation, the `scaled_hidden_dim` idiom).
    pub fn resolve_scaled(&mut self, field: &str, reference_dim: i64) -> Result<(), ConfigError> {
        if let Value::ScaledDim(m) = self.get(field)? {
            let resolved = (m * reference_dim as f64).round() as i64;
            self.set(field, Value::Int(resolved))?;
        }
        Ok(())
    }
}

fn parse_segment(seg: &str) -> Option<(&str, Option<usize>)> {
    if let Some(open) = seg.find('[') {
        let close = seg.rfind(']')?;
        let idx = seg[open + 1..close].parse().ok()?;
        Some((&seg[..open], Some(idx)))
    } else {
        Some((seg, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear() -> ConfigNode {
        ConfigNode::new("Linear")
            .field("input_dim", Value::Null)
            .field("output_dim", Value::Null)
            .field("use_bias", Value::Bool(false))
    }

    #[test]
    fn set_get_roundtrip() {
        let mut c = linear();
        c.set("input_dim", Value::Int(4)).unwrap();
        assert_eq!(c.get_int("input_dim").unwrap(), 4);
    }

    #[test]
    fn strict_unknown_field_rejected() {
        let mut c = linear();
        let err = c.set("rope_theta", Value::Float(1e4)).unwrap_err();
        assert!(matches!(err, ConfigError::UnknownField { .. }));
        assert!(err.to_string().contains("rope_theta"));
    }

    #[test]
    fn type_mismatch_reported() {
        let mut c = linear();
        c.set("use_bias", Value::Bool(true)).unwrap();
        let err = c.get_int("use_bias").unwrap_err();
        assert!(matches!(err, ConfigError::TypeMismatch { .. }));
    }

    #[test]
    fn require_unset_fails() {
        let c = linear();
        assert!(matches!(
            c.require("input_dim").unwrap_err(),
            ConfigError::RequiredUnset { .. }
        ));
    }

    #[test]
    fn path_navigation() {
        let layer = ConfigNode::new("TransformerLayer")
            .field("self_attention", Value::Config(ConfigNode::new("Attention").field("num_heads", Value::Int(8))))
            .field("feed_forward", Value::Config(linear()));
        let root = ConfigNode::new("Decoder").field("layer", Value::Config(layer));
        assert_eq!(root.at_path("layer.self_attention").unwrap().klass, "Attention");
        assert_eq!(
            root.at_path("layer.self_attention").unwrap().get_int("num_heads").unwrap(),
            8
        );
        assert!(root.at_path("layer.bogus").is_err());
    }

    #[test]
    fn path_list_indexing() {
        let layers = vec![ConfigNode::new("L0"), ConfigNode::new("L1")];
        let root = ConfigNode::new("Stack").field("layers", Value::ConfigList(layers));
        assert_eq!(root.at_path("layers[1]").unwrap().klass, "L1");
        assert!(root.at_path("layers[2]").is_err());
    }

    #[test]
    fn scaled_dim_resolution() {
        let mut c = linear();
        c.set("output_dim", Value::ScaledDim(8.0 / 3.0)).unwrap();
        c.resolve_scaled("output_dim", 768).unwrap();
        assert_eq!(c.get_int("output_dim").unwrap(), 2048);
    }

    #[test]
    fn children_enumeration() {
        let root = ConfigNode::new("P")
            .field("a", Value::Config(ConfigNode::new("A")))
            .field("xs", Value::ConfigList(vec![ConfigNode::new("X")]))
            .field("n", Value::Int(1));
        let kids = root.children();
        let names: Vec<_> = kids.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a", "xs[0]"]);
    }

    #[test]
    fn mutation_through_path() {
        let mut root = ConfigNode::new("P").field("a", Value::Config(linear()));
        root.at_path_mut("a").unwrap().set("input_dim", Value::Int(3)).unwrap();
        assert_eq!(root.at_path("a").unwrap().get_int("input_dim").unwrap(), 3);
    }
}
