//! The hardware-agnostic training boundary (paper §4.2 applied to
//! training, mirroring [`crate::runtime::backend::ComputeBackend`] for
//! serving): the trainer loop, the data-parallel trainer, and the fleet
//! orchestrator never touch PJRT, artifacts, or mock state — they see
//! init/step/eval/state ops plus a discovered descriptor, so training
//! substrates and orchestration policies compose freely.
//!
//! Two implementations ship with the crate:
//!
//! * [`PjrtTrainBackend`] — the real substrate: wraps
//!   [`crate::runtime::TrainSession`] (AOT train-step artifacts through
//!   PJRT); step time is measured wall time.
//! * [`MockTrainBackend`] — a deterministic pure-Rust optimizer over a
//!   small parameter vector: same state layout as the real session
//!   (params + adam moments + step counter), bit-exact save/restore,
//!   loss that genuinely descends.  The workhorse of fleet/recovery
//!   tests and benches — whole failure storms replay in microseconds
//!   with no artifacts on disk.
//!
//! A new backend is ~10 lines of mechanism: implement the ops, return a
//! descriptor, and the trainer loop, `train_data_parallel`, and
//! [`crate::distributed::fleet::FleetTrainer`] work unchanged.  See
//! `docs/training.md`.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ConfigNode;
use crate::runtime::{Manifest, RuntimeClient, TrainSession};

/// What a training substrate looks like from above — discovered at
/// runtime, never assumed by the orchestration layer.
#[derive(Clone, Debug)]
pub struct TrainBackendDescriptor {
    pub name: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// True when step costs are measured wall time (PJRT); false when
    /// the backend is virtual (mock).
    pub measured_time: bool,
}

/// The trait boundary between training orchestration and compute
/// substrates.
///
/// The contract mirrors the AOT train-step artifacts: seeded in-graph
/// init, a step that consumes a [batch, seq] token/target pair and
/// returns the scalar loss, forward-only eval, and a host-roundtrippable
/// flat state vector (params, opt moments, step counter) — the unit of
/// checkpointing, parameter synchronization, and failure recovery.
pub trait TrainBackend {
    fn descriptor(&self) -> &TrainBackendDescriptor;

    /// Initialize the train state from a seed (same seed ⇒ bit-identical
    /// state, the property data-parallel replication relies on).
    fn init(&mut self, seed: i32) -> Result<()>;

    /// One training step; returns the scalar loss.
    fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32>;

    /// Forward-only loss on a batch (no state update). Deterministic on
    /// a healthy host: re-running on frozen inputs must be bit-identical
    /// (the SDC sweep depends on this).
    fn eval_loss(&self, tokens: &[i32], targets: &[i32]) -> Result<f32>;

    /// Whether [`TrainBackend::eval_loss`] is available (some artifact
    /// families ship without a forward-only graph).
    fn supports_eval(&self) -> bool {
        true
    }

    /// Snapshot the full train state to host vectors (checkpointing,
    /// parameter sync). (name, data) pairs in canonical order.
    fn state_to_host(&self) -> Result<Vec<(String, Vec<f32>)>>;

    /// Restore the full train state from host vectors.
    fn restore_from_host(&mut self, tensors: &[(String, Vec<f32>)], step: u64) -> Result<()>;

    fn steps_done(&self) -> u64;

    /// Number of leading state tensors that are model parameters.
    fn num_params(&self) -> usize;
}

// ---------------------------------------------------------------------------
// PJRT (the real substrate)
// ---------------------------------------------------------------------------

/// The real backend: AOT train-step artifacts executed through PJRT.
pub struct PjrtTrainBackend {
    session: TrainSession,
    desc: TrainBackendDescriptor,
}

impl PjrtTrainBackend {
    /// Open a session for artifact family `base` ("tiny", "small_moe", …).
    pub fn open(client: Arc<RuntimeClient>, manifest: &Manifest, base: &str) -> Result<Self> {
        let session = TrainSession::open(client, manifest, base)
            .with_context(|| format!("opening train session {base:?}"))?;
        Ok(PjrtTrainBackend::from_session(session, base))
    }

    /// Wrap an already-open session.
    pub fn from_session(session: TrainSession, base: &str) -> Self {
        let desc = TrainBackendDescriptor {
            name: format!("pjrt:{base}"),
            batch: session.batch,
            seq: session.seq,
            vocab: session.artifact.hyper.get("vocab_size").copied().unwrap_or(256) as usize,
            measured_time: true,
        };
        PjrtTrainBackend { session, desc }
    }
}

impl TrainBackend for PjrtTrainBackend {
    fn descriptor(&self) -> &TrainBackendDescriptor {
        &self.desc
    }

    fn init(&mut self, seed: i32) -> Result<()> {
        self.session.init(seed)
    }

    fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        self.session.step(tokens, targets)
    }

    fn eval_loss(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        self.session.eval_loss(tokens, targets)
    }

    fn supports_eval(&self) -> bool {
        self.session.has_eval()
    }

    fn state_to_host(&self) -> Result<Vec<(String, Vec<f32>)>> {
        self.session.state_to_host()
    }

    fn restore_from_host(&mut self, tensors: &[(String, Vec<f32>)], step: u64) -> Result<()> {
        self.session.restore_from_host(tensors, step)
    }

    fn steps_done(&self) -> u64 {
        self.session.steps_done
    }

    fn num_params(&self) -> usize {
        self.session.num_params()
    }
}

// ---------------------------------------------------------------------------
// Mock (deterministic fleet tests / benches)
// ---------------------------------------------------------------------------

/// Options for [`MockTrainBackend`].
#[derive(Clone, Debug)]
pub struct MockTrainBackendOptions {
    /// Parameter-vector length.
    pub dim: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub lr: f32,
}

impl Default for MockTrainBackendOptions {
    fn default() -> Self {
        MockTrainBackendOptions {
            dim: 64,
            batch: 2,
            seq: 32,
            vocab: 256,
            lr: 0.2,
        }
    }
}

/// Deterministic pure-Rust training substrate.
///
/// State mirrors the real session layout — `params`, `opt_m`, `opt_v`,
/// and a trailing step counter — so the checkpointer, the all-reduce
/// parameter sync, and multi-tier restore exercise the same code paths
/// as PJRT training.  The "gradient" pulls parameters toward zero with a
/// data-dependent perturbation, so the loss (a quadratic in the
/// parameters) genuinely descends, and every step is a pure function of
/// (state, batch): replaying from a restored checkpoint is bit-identical
/// to never having failed.
pub struct MockTrainBackend {
    opts: MockTrainBackendOptions,
    desc: TrainBackendDescriptor,
    params: Vec<f32>,
    opt_m: Vec<f32>,
    opt_v: Vec<f32>,
    step: u64,
    initialized: bool,
}

// The SplitMix64 mixer family (init noise, gradient noise, batch
// digests) lives in the shared backend core next to its serving mirror.
use crate::backend::{digest, mix, unit};

impl MockTrainBackend {
    pub fn new(opts: MockTrainBackendOptions) -> Self {
        let desc = TrainBackendDescriptor {
            name: "mock-train".into(),
            batch: opts.batch,
            seq: opts.seq,
            vocab: opts.vocab,
            measured_time: false,
        };
        let dim = opts.dim;
        MockTrainBackend {
            opts,
            desc,
            params: vec![0.0; dim],
            opt_m: vec![0.0; dim],
            opt_v: vec![0.0; dim],
            step: 0,
            initialized: false,
        }
    }

    fn check_batch(&self, tokens: &[i32], targets: &[i32]) -> Result<()> {
        let expect = self.desc.batch * self.desc.seq;
        anyhow::ensure!(
            tokens.len() == expect && targets.len() == expect,
            "batch shape mismatch: got {}/{} tokens/targets, backend wants {} ({}x{})",
            tokens.len(),
            targets.len(),
            expect,
            self.desc.batch,
            self.desc.seq
        );
        Ok(())
    }

    fn loss(&self, batch_digest: u64) -> f32 {
        let mean_sq =
            self.params.iter().map(|p| p * p).sum::<f32>() / self.opts.dim.max(1) as f32;
        // quadratic bowl over the parameters + a small data term, so the
        // curve descends toward a floor instead of collapsing to zero
        0.69 + 4.0 * mean_sq + 1e-3 * unit(batch_digest).abs()
    }
}

impl TrainBackend for MockTrainBackend {
    fn descriptor(&self) -> &TrainBackendDescriptor {
        &self.desc
    }

    fn init(&mut self, seed: i32) -> Result<()> {
        for (i, p) in self.params.iter_mut().enumerate() {
            *p = 0.5 * unit(mix(seed as u32 as u64, i as u64));
        }
        self.opt_m.iter_mut().for_each(|x| *x = 0.0);
        self.opt_v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
        self.initialized = true;
        Ok(())
    }

    fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        anyhow::ensure!(self.initialized, "MockTrainBackend::step before init/restore");
        self.check_batch(tokens, targets)?;
        let d = mix(digest(tokens), digest(targets));
        for i in 0..self.opts.dim {
            let noise = unit(mix(d, i as u64));
            // gradient of 0.5·p² plus a data-dependent perturbation
            let g = self.params[i] + 0.05 * noise;
            self.opt_m[i] = 0.9 * self.opt_m[i] + 0.1 * g;
            self.opt_v[i] = 0.99 * self.opt_v[i] + 0.01 * g * g;
            self.params[i] -= self.opts.lr * g;
        }
        self.step += 1;
        Ok(self.loss(d))
    }

    fn eval_loss(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        anyhow::ensure!(self.initialized, "MockTrainBackend::eval_loss before init/restore");
        self.check_batch(tokens, targets)?;
        Ok(self.loss(mix(digest(tokens), digest(targets))))
    }

    fn state_to_host(&self) -> Result<Vec<(String, Vec<f32>)>> {
        anyhow::ensure!(self.initialized, "MockTrainBackend: no state to snapshot");
        Ok(vec![
            ("params".into(), self.params.clone()),
            ("opt_m".into(), self.opt_m.clone()),
            ("opt_v".into(), self.opt_v.clone()),
            ("step".into(), vec![self.step as f32]),
        ])
    }

    fn restore_from_host(&mut self, tensors: &[(String, Vec<f32>)], step: u64) -> Result<()> {
        anyhow::ensure!(
            tensors.len() == 4,
            "restore: got {} tensors, expected 4",
            tensors.len()
        );
        for (got, want) in tensors.iter().zip(["params", "opt_m", "opt_v", "step"]) {
            anyhow::ensure!(
                got.0 == want,
                "restore: tensor order mismatch: {} vs {}",
                got.0,
                want
            );
        }
        for t in &tensors[..3] {
            anyhow::ensure!(
                t.1.len() == self.opts.dim,
                "restore: {} has {} elems, expected {}",
                t.0,
                t.1.len(),
                self.opts.dim
            );
        }
        self.params = tensors[0].1.clone();
        self.opt_m = tensors[1].1.clone();
        self.opt_v = tensors[2].1.clone();
        self.step = step;
        self.initialized = true;
        Ok(())
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn num_params(&self) -> usize {
        1 // one "params" tensor leads the state vector
    }
}

// ---------------------------------------------------------------------------
// Config-driven construction
// ---------------------------------------------------------------------------

/// Build a train backend from its registered config (`MockTrainBackend`).
/// `PjrtTrainBackend` configs carry only the artifact family — the
/// session needs a live PJRT client, so construct those with
/// [`PjrtTrainBackend::open`].
///
/// Thin delegate: the construction logic lives in the shared registry
/// path ([`crate::backend::train_backend_from_config`]), alongside its
/// serving mirror and the family-agnostic
/// [`crate::backend::any_backend_from_config`].
pub fn train_backend_from_config(cfg: &ConfigNode) -> Result<Box<dyn TrainBackend>> {
    crate::backend::train_backend_from_config(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::input::{CorpusKind, SyntheticCorpus};
    use crate::trainer::InputPipeline;

    fn mock() -> MockTrainBackend {
        MockTrainBackend::new(MockTrainBackendOptions::default())
    }

    fn corpus_for(b: &dyn TrainBackend, seed: u64) -> SyntheticCorpus {
        let d = b.descriptor();
        SyntheticCorpus::new(CorpusKind::Markov, d.vocab, d.batch, d.seq, seed)
    }

    fn state_bits(b: &dyn TrainBackend) -> Vec<(String, Vec<u32>)> {
        b.state_to_host()
            .unwrap()
            .into_iter()
            .map(|(n, v)| (n, v.iter().map(|x| x.to_bits()).collect()))
            .collect()
    }

    #[test]
    fn mock_is_deterministic() {
        let (mut a, mut b) = (mock(), mock());
        a.init(7).unwrap();
        b.init(7).unwrap();
        let mut ca = corpus_for(&a, 1);
        let mut cb = corpus_for(&b, 1);
        for _ in 0..5 {
            let (ta, ga) = ca.next_batch();
            let (tb, gb) = cb.next_batch();
            assert_eq!(
                a.step(&ta, &ga).unwrap().to_bits(),
                b.step(&tb, &gb).unwrap().to_bits()
            );
        }
        assert_eq!(state_bits(&a), state_bits(&b));
    }

    #[test]
    fn mock_loss_descends() {
        let mut b = mock();
        b.init(0).unwrap();
        let mut c = corpus_for(&b, 0);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let (t, g) = c.next_batch();
            losses.push(b.step(&t, &g).unwrap());
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "head {head} tail {tail}");
        assert_eq!(b.steps_done(), 30);
    }

    #[test]
    fn restore_then_replay_is_bit_identical() {
        // the property fleet recovery rests on: resuming from a snapshot
        // and replaying the same batches reproduces the exact trajectory
        let mut full = mock();
        full.init(3).unwrap();
        let mut c = corpus_for(&full, 9);
        let mut snapshot = None;
        for s in 1..=8 {
            let (t, g) = c.next_batch();
            full.step(&t, &g).unwrap();
            if s == 5 {
                snapshot = Some(full.state_to_host().unwrap());
            }
        }
        let mut resumed = mock();
        resumed.restore_from_host(&snapshot.unwrap(), 5).unwrap();
        assert_eq!(resumed.steps_done(), 5);
        let mut c2 = corpus_for(&resumed, 9);
        for _ in 0..5 {
            c2.next_batch(); // replay consumed batches
        }
        for _ in 6..=8 {
            let (t, g) = c2.next_batch();
            resumed.step(&t, &g).unwrap();
        }
        assert_eq!(state_bits(&full), state_bits(&resumed));
    }

    #[test]
    fn eval_is_pure_and_bit_stable() {
        let mut b = mock();
        b.init(1).unwrap();
        let mut c = corpus_for(&b, 2);
        let (t, g) = c.next_batch();
        let before = state_bits(&b);
        let e1 = b.eval_loss(&t, &g).unwrap();
        let e2 = b.eval_loss(&t, &g).unwrap();
        assert_eq!(e1.to_bits(), e2.to_bits());
        assert_eq!(before, state_bits(&b), "eval must not mutate state");
        assert!(b.supports_eval());
    }

    #[test]
    fn step_before_init_rejected() {
        let mut b = mock();
        let t = vec![0i32; 64];
        assert!(b.step(&t, &t).is_err());
        assert!(b.state_to_host().is_err());
    }

    #[test]
    fn backend_from_config_builds_mock_not_pjrt() {
        use crate::config::registry::default_config;
        let mock = train_backend_from_config(&default_config("MockTrainBackend").unwrap()).unwrap();
        assert_eq!(mock.descriptor().name, "mock-train");
        assert!(!mock.descriptor().measured_time);
        // pjrt configs compose, but construction needs a live session
        assert!(train_backend_from_config(&default_config("PjrtTrainBackend").unwrap()).is_err());
    }
}
