//! The evaler: periodic held-out evaluation during training.
//!
//! AXLearn's trainer composes child modules including evalers (§3's
//! module tree); like everything else it is swappable by config.  Ours
//! evaluates the forward-only `eval_loss` artifact on a held-out stream
//! of the input pipeline (a different seed of the same corpus), so
//! train/eval divergence — the classic overfitting probe — is observable
//! from the Rust side with no Python.

use anyhow::Result;

use super::backend::TrainBackend;
use super::input::InputPipeline;

/// One evaluation record.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub step: u64,
    pub eval_loss: f64,
    pub batches: usize,
}

/// Periodic evaluator over a held-out pipeline.
pub struct Evaler {
    pub every_n_steps: u64,
    pub num_batches: usize,
    pub records: Vec<EvalRecord>,
}

impl Evaler {
    pub fn new(every_n_steps: u64, num_batches: usize) -> Self {
        Evaler {
            every_n_steps,
            num_batches: num_batches.max(1),
            records: Vec::new(),
        }
    }

    /// Run an eval sweep if the step is on the cadence. Returns the eval
    /// loss when one ran.
    pub fn maybe_eval(
        &mut self,
        step: u64,
        backend: &dyn TrainBackend,
        heldout: &mut dyn InputPipeline,
    ) -> Result<Option<f64>> {
        if self.every_n_steps == 0 || step == 0 || step % self.every_n_steps != 0 {
            return Ok(None);
        }
        let mut total = 0.0f64;
        for _ in 0..self.num_batches {
            let (tok, tgt) = heldout.next_batch();
            total += backend.eval_loss(&tok, &tgt)? as f64;
        }
        let mean = total / self.num_batches as f64;
        self.records.push(EvalRecord {
            step,
            eval_loss: mean,
            batches: self.num_batches,
        });
        Ok(Some(mean))
    }

    pub fn best(&self) -> Option<&EvalRecord> {
        self.records
            .iter()
            .min_by(|a, b| a.eval_loss.partial_cmp(&b.eval_loss).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_gating_without_session() {
        // cadence logic is session-independent: verify the gate directly
        let e = Evaler::new(10, 2);
        for step in [1u64, 5, 9, 11, 15] {
            assert_ne!(step % e.every_n_steps, 0);
        }
        assert_eq!(20 % e.every_n_steps, 0);
    }

    #[test]
    fn best_picks_minimum() {
        let mut e = Evaler::new(1, 1);
        for (s, l) in [(1u64, 3.0f64), (2, 2.1), (3, 2.7)] {
            e.records.push(EvalRecord {
                step: s,
                eval_loss: l,
                batches: 1,
            });
        }
        assert_eq!(e.best().unwrap().step, 2);
    }
}
