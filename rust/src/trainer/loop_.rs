//! The training loop: the root module's run method.
//!
//! Wires together the train backend, input pipeline, checkpointer,
//! watchdog, SDC checker, goodput tracker, and the InvocationContext —
//! each swappable, none aware of the others' internals (§3, §4.3).
//!
//! The loop is written against the [`TrainBackend`] boundary: PJRT
//! sessions and the deterministic mock run through the identical code
//! path ([`train`] is a thin wrapper that opens the PJRT backend;
//! [`train_backend`] is the loop itself).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::checkpoint::format::CheckpointData;
use crate::checkpoint::saver::{Checkpointer, CheckpointerOptions};
use crate::module::InvocationContext;
use crate::monitor::goodput::{EventKind, GoodputTracker};
use crate::monitor::watchdog::{Watchdog, WatchdogAction, WatchdogOptions};
use crate::runtime::{Manifest, RuntimeClient};

use super::backend::{PjrtTrainBackend, TrainBackend};
use super::input::InputPipeline;
use super::metrics::{MetricsLog, StepRecord};

/// Options for a local training run.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// Artifact family ("tiny", "small", "small_moe", "base100m", ...).
    pub artifact: String,
    pub max_steps: u64,
    pub seed: i32,
    pub log_every: u64,
    /// Checkpoint every n steps (0 = disabled).
    pub checkpoint_every: u64,
    pub checkpoint: CheckpointerOptions,
    /// Run an SDC sweep every n steps (0 = disabled).
    pub sdc_every: u64,
    /// Evaluate on a held-out stream every n steps (0 = disabled).
    pub eval_every: u64,
    /// Resume from the latest checkpoint if present.
    pub resume: bool,
    /// Record phase timings (on-demand profiler, §5).
    pub profile: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            artifact: "tiny".into(),
            max_steps: 20,
            seed: 0,
            log_every: 10,
            checkpoint_every: 0,
            checkpoint: CheckpointerOptions::default(),
            sdc_every: 0,
            eval_every: 0,
            resume: false,
            profile: false,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub metrics: MetricsLog,
    pub goodput: GoodputTracker,
    pub evals: Vec<super::evaler::EvalRecord>,
    pub profile_report: Option<String>,
    pub final_step: u64,
    pub first_loss: f32,
    pub final_loss: f32,
    pub watchdog_trips: u64,
    pub resumed_from: Option<u64>,
    /// Checkpoint saves started (the duplicate-final-save regression
    /// guard: a step already durable is never saved twice).
    pub checkpoint_saves: u64,
}

/// Run training locally on the CPU PJRT client.
pub fn train(
    client: Arc<RuntimeClient>,
    manifest: &Manifest,
    input: &mut dyn InputPipeline,
    opts: &TrainerOptions,
) -> Result<TrainOutcome> {
    let mut backend = PjrtTrainBackend::open(client, manifest, &opts.artifact)?;
    train_backend(&mut backend, input, opts)
}

/// Run training over any [`TrainBackend`].
pub fn train_backend(
    backend: &mut dyn TrainBackend,
    input: &mut dyn InputPipeline,
    opts: &TrainerOptions,
) -> Result<TrainOutcome> {
    let mut ctx = InvocationContext::new("trainer", opts.seed as u64);
    let desc = backend.descriptor().clone();
    anyhow::ensure!(
        input.batch() == desc.batch && input.seq() == desc.seq,
        "input pipeline {}x{} does not match backend {} {}x{}",
        input.batch(),
        input.seq(),
        desc.name,
        desc.batch,
        desc.seq
    );

    let mut goodput = GoodputTracker::new();
    let wall0 = Instant::now();
    let now = |w: &Instant| w.elapsed().as_secs_f64();
    goodput.record(EventKind::JobStart, 0.0, 0);

    let mut checkpointer = if opts.checkpoint_every > 0 {
        Some(Checkpointer::new(opts.checkpoint.clone())?)
    } else {
        None
    };

    // init or resume
    let mut resumed_from = None;
    let restored = match (&checkpointer, opts.resume) {
        (Some(c), true) => c.restore_latest()?,
        _ => None,
    };
    match restored {
        Some(data) => {
            let step = data.step;
            backend.restore_from_host(&data.tensors, step)?;
            resumed_from = Some(step);
        }
        None => backend.init(opts.seed)?,
    }
    goodput.record(EventKind::CompilationDone, now(&wall0), 0);
    goodput.record(EventKind::RestartDone, now(&wall0), backend.steps_done());

    let mut metrics = MetricsLog::new();
    let mut watchdog = Watchdog::new(WatchdogOptions::default());
    let mut profiler = crate::monitor::Profiler::new(opts.profile);
    let mut evaler = super::evaler::Evaler::new(opts.eval_every, 2);
    // held-out stream: same corpus family, different seed
    let mut heldout = super::input::SyntheticCorpus::new(
        super::input::CorpusKind::Markov,
        desc.vocab,
        desc.batch,
        desc.seq,
        (opts.seed as u64) ^ 0xE7A1,
    );
    let mut sdc = crate::monitor::sdc::SdcChecker::new(2, false);
    let tokens_per_step = (desc.batch * desc.seq) as u64;
    let mut first_loss = f32::NAN;
    let mut final_loss = f32::NAN;
    let mut checkpoint_saves = 0u64;
    // last step known durable: the in-loop cadence save, or the restored
    // checkpoint itself (resuming a finished run must not re-save it)
    let mut last_saved_step = resumed_from;

    while backend.steps_done() < opts.max_steps {
        profiler.begin("train");
        let (tokens, targets) = profiler.scope("input", || input.next_batch());
        let t0 = Instant::now();
        profiler.begin("step");
        let loss = ctx.scope("model", |_| backend.step(&tokens, &targets))?;
        profiler.end();
        let dt = t0.elapsed().as_secs_f64();
        let step = backend.steps_done();
        if first_loss.is_nan() {
            first_loss = loss;
        }
        final_loss = loss;
        ctx.scalar("loss", loss as f64);
        ctx.counter("tokens", tokens_per_step as f64);
        goodput.record(EventKind::StepDone, now(&wall0), step);
        metrics.push(StepRecord {
            step,
            loss,
            step_time_s: dt,
            tokens: tokens_per_step,
        });

        match watchdog.observe_step(dt, 1.0) {
            WatchdogAction::Ok => {}
            action => {
                // local runs cannot actually hang-restart; record and go on
                ctx.counter("watchdog_trips", 1.0);
                let _ = action;
            }
        }

        if opts.sdc_every > 0 && step % opts.sdc_every == 0 && backend.supports_eval() {
            // Re-run the eval loss on frozen inputs: results must be
            // bit-identical on a healthy host.  The first execution seeds
            // the sweep as its reference (no discarded run), and eval
            // errors propagate instead of silently skipping the check.
            let mut first = Some(backend.eval_loss(&tokens, &targets)?);
            let report = sdc.sweep(|_| match first.take() {
                Some(reference) => Ok(vec![reference]),
                None => Ok(vec![backend.eval_loss(&tokens, &targets)?]),
            })?;
            anyhow::ensure!(report.healthy(), "SDC detected at step {step}: {report:?}");
        }

        if let Some(loss) = evaler.maybe_eval(step, &*backend, &mut heldout)? {
            ctx.scalar("eval_loss", loss);
        }

        if let Some(c) = checkpointer.as_mut() {
            if step > 0 && step % opts.checkpoint_every == 0 {
                profiler.begin("checkpoint");
                let data = CheckpointData {
                    step,
                    tensors: backend.state_to_host()?,
                };
                c.save(data)?;
                checkpoint_saves += 1;
                last_saved_step = Some(step);
                profiler.end();
                goodput.record(EventKind::CheckpointDurable, now(&wall0), step);
            }
        }
        profiler.end(); // train
    }

    // final checkpoint + flush — skipped when the last loop iteration
    // already saved this step (max_steps % checkpoint_every == 0 used to
    // trigger a redundant blocking save on the async saver)
    if let Some(c) = checkpointer.as_mut() {
        let final_step = backend.steps_done();
        if last_saved_step != Some(final_step) {
            let data = CheckpointData {
                step: final_step,
                tensors: backend.state_to_host()?,
            };
            c.save(data)?;
            checkpoint_saves += 1;
            goodput.record(EventKind::CheckpointDurable, now(&wall0), final_step);
        }
        c.flush()?;
    }
    goodput.record(EventKind::JobEnd, now(&wall0), backend.steps_done());

    Ok(TrainOutcome {
        metrics,
        goodput,
        evals: evaler.records,
        profile_report: if opts.profile { Some(profiler.report()) } else { None },
        final_step: backend.steps_done(),
        first_loss,
        final_loss,
        watchdog_trips: watchdog.trips,
        resumed_from,
        checkpoint_saves,
    })
}
