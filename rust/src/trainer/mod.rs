//! The trainer: AXLearn's root module (§3).
//!
//! Composes the input pipeline, the AOT-compiled model (via
//! [`crate::runtime::TrainSession`]), the checkpointer, the watchdog, and
//! the summary writer — all of them swappable by config, which is the
//! paper's core claim ("any module is replaceable, including the input
//! pipeline, checkpointer, trainer loop").

pub mod evaler;
pub mod input;
pub mod loop_;
pub mod metrics;

pub use evaler::Evaler;
pub use input::{InputPipeline, SyntheticCorpus};
pub use loop_::{train, TrainOutcome, TrainerOptions};
pub use metrics::{MetricsLog, StepRecord};
