//! The trainer: AXLearn's root module (§3).
//!
//! Composes the input pipeline, the AOT-compiled model (via
//! [`crate::runtime::TrainSession`]), the checkpointer, the watchdog, and
//! the summary writer — all of them swappable by config, which is the
//! paper's core claim ("any module is replaceable, including the input
//! pipeline, checkpointer, trainer loop").
//!
//! The compute substrate itself is swappable through the [`TrainBackend`]
//! boundary ([`backend`]): the loop, the data-parallel trainer, and the
//! fleet orchestrator ([`crate::distributed::fleet`]) are policies over
//! that trait, exactly as serving schedulers are policies over
//! [`crate::runtime::backend::ComputeBackend`].

pub mod backend;
pub mod evaler;
pub mod input;
pub mod loop_;
pub mod metrics;

pub use backend::{
    train_backend_from_config, MockTrainBackend, MockTrainBackendOptions, PjrtTrainBackend,
    TrainBackend, TrainBackendDescriptor,
};
pub use evaler::Evaler;
pub use input::{InputPipeline, SyntheticCorpus};
pub use loop_::{train, train_backend, TrainOutcome, TrainerOptions};
pub use metrics::{MetricsLog, StepRecord};
