//! Input pipeline: deterministic synthetic LM corpora.
//!
//! The paper's input module is swappable like everything else; ours
//! generates synthetic next-token-prediction data.  The default "markov"
//! corpus is a random sparse Markov chain over the vocabulary — unlike
//! uniform noise it has real (low-entropy) structure, so the training
//! loss curve *must* descend well below log(vocab) if the whole stack
//! (kernel → model → optimizer → runtime) is correct.  That makes the
//! e2e example a genuine correctness probe, not a smoke test.

use crate::util::rng::Rng;

/// A batch iterator yielding (tokens, targets) of shape [batch, seq].
pub trait InputPipeline {
    fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>);
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
}

/// Sparse-Markov synthetic corpus.
pub struct SyntheticCorpus {
    rng: Rng,
    vocab: usize,
    batch: usize,
    seq: usize,
    /// `transitions[v]` = candidate next tokens for v.
    transitions: Vec<Vec<i32>>,
    kind: CorpusKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// Sparse Markov chain (learnable structure).
    Markov,
    /// Uniform random tokens (loss should plateau at ~log vocab).
    Uniform,
    /// Real English text (this repo's own docs), char-level tokenized —
    /// requires vocab >= 256. The "tiny corpus" option of the e2e story.
    Text,
}

/// The bundled real-text corpus: the repository's own documentation
/// (genuine English prose, no licensing concerns, deterministic).
pub const BUNDLED_TEXT: &str = concat!(
    include_str!("../../../README.md"),
    include_str!("../../../DESIGN.md"),
);

impl SyntheticCorpus {
    pub fn new(kind: CorpusKind, vocab: usize, batch: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        // each token has a small out-degree => low conditional entropy
        let out_degree = 4.min(vocab);
        let transitions = (0..vocab)
            .map(|_| {
                (0..out_degree)
                    .map(|_| rng.gen_range(0, vocab as u64) as i32)
                    .collect()
            })
            .collect();
        SyntheticCorpus {
            rng: Rng::new(seed),
            vocab,
            batch,
            seq,
            transitions,
            kind,
        }
    }

    /// Per-token conditional entropy of the markov corpus (nats) — the
    /// loss floor the model should approach.
    pub fn entropy_floor(&self) -> f64 {
        match self.kind {
            CorpusKind::Uniform => (self.vocab as f64).ln(),
            // out-degree-4 uniform transitions, sampled with replacement:
            // <= ln 4 (duplicates lower it); ln 4 is the safe upper floor
            CorpusKind::Markov => 4f64.ln(),
            // English char-level entropy ~= 2.3 bits/char ~= 1.6 nats
            CorpusKind::Text => 1.6,
        }
    }

    fn sample_row(&mut self, out_tokens: &mut [i32], out_targets: &mut [i32]) {
        match self.kind {
            CorpusKind::Uniform => {
                for t in out_tokens.iter_mut() {
                    *t = self.rng.gen_range(0, self.vocab as u64) as i32;
                }
            }
            CorpusKind::Text => {
                // char-level window into the bundled docs
                let bytes = BUNDLED_TEXT.as_bytes();
                let max_start = bytes.len().saturating_sub(out_tokens.len() + 1);
                let start = self.rng.gen_range(0, max_start as u64) as usize;
                for (t, &b) in out_tokens.iter_mut().zip(&bytes[start..]) {
                    *t = (b as i32).min(self.vocab as i32 - 1);
                }
            }
            CorpusKind::Markov => {
                let mut cur = self.rng.gen_range(0, self.vocab as u64) as i32;
                for t in out_tokens.iter_mut() {
                    *t = cur;
                    let nexts = &self.transitions[cur as usize];
                    cur = nexts[self.rng.gen_range(0, nexts.len() as u64) as usize];
                }
            }
        }
        let n = out_tokens.len();
        out_targets[..n - 1].copy_from_slice(&out_tokens[1..]);
        out_targets[n - 1] = -1; // mask final position
    }
}

impl InputPipeline for SyntheticCorpus {
    fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; self.batch * self.seq];
        let mut targets = vec![0i32; self.batch * self.seq];
        for b in 0..self.batch {
            let lo = b * self.seq;
            let hi = lo + self.seq;
            // split_at_mut juggling avoided: index separate slices
            let (tok_row, tgt_row) = (&mut tokens[lo..hi], &mut targets[lo..hi]);
            // sample_row needs &mut self; do it in two steps
            let mut tr = vec![0i32; self.seq];
            let mut gr = vec![0i32; self.seq];
            self.sample_row(&mut tr, &mut gr);
            tok_row.copy_from_slice(&tr);
            tgt_row.copy_from_slice(&gr);
        }
        (tokens, targets)
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_masking() {
        let mut c = SyntheticCorpus::new(CorpusKind::Markov, 256, 3, 16, 0);
        let (tok, tgt) = c.next_batch();
        assert_eq!(tok.len(), 48);
        assert_eq!(tgt.len(), 48);
        for b in 0..3 {
            assert_eq!(tgt[b * 16 + 15], -1, "final target masked");
            // targets are tokens shifted by one
            for i in 0..15 {
                assert_eq!(tgt[b * 16 + i], tok[b * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = SyntheticCorpus::new(CorpusKind::Uniform, 100, 2, 32, 1);
        let (tok, _) = c.next_batch();
        assert!(tok.iter().all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(CorpusKind::Markov, 256, 2, 16, 42);
        let mut b = SyntheticCorpus::new(CorpusKind::Markov, 256, 2, 16, 42);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SyntheticCorpus::new(CorpusKind::Markov, 256, 2, 16, 1);
        let mut b = SyntheticCorpus::new(CorpusKind::Markov, 256, 2, 16, 2);
        assert_ne!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn markov_is_predictable_structure() {
        // every observed transition must be one of the token's candidates
        let mut c = SyntheticCorpus::new(CorpusKind::Markov, 64, 1, 128, 7);
        let transitions = c.transitions.clone();
        let (tok, _) = c.next_batch();
        for w in tok.windows(2) {
            assert!(
                transitions[w[0] as usize].contains(&w[1]),
                "illegal transition {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn entropy_floor_sane() {
        let m = SyntheticCorpus::new(CorpusKind::Markov, 2048, 1, 8, 0);
        assert!(m.entropy_floor() < (2048f64).ln() / 2.0);
        let u = SyntheticCorpus::new(CorpusKind::Uniform, 2048, 1, 8, 0);
        assert!((u.entropy_floor() - (2048f64).ln()).abs() < 1e-9);
    }
}
