//! Step metrics and the summary log (what the paper's monitoring layer
//! records per step; consumed by the watchdog and goodput tracker).

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// One training step's record.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub step_time_s: f64,
    pub tokens: u64,
}

/// In-memory metrics log with CSV/JSON export.
#[derive(Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `n` steps.
    pub fn mean_loss_tail(&self, n: usize) -> Option<f64> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss as f64).sum::<f64>() / tail.len() as f64)
    }

    pub fn tokens_per_second(&self) -> f64 {
        let total_tokens: u64 = self.records.iter().map(|r| r.tokens).sum();
        let total_time: f64 = self.records.iter().map(|r| r.step_time_s).sum();
        if total_time > 0.0 {
            total_tokens as f64 / total_time
        } else {
            0.0
        }
    }

    /// Write a loss-curve CSV (step,loss,step_time_s).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,step_time_s,tokens")?;
        for r in &self.records {
            writeln!(f, "{},{},{:.6},{}", r.step, r.loss, r.step_time_s, r.tokens)?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("step", Json::num(r.step as f64)),
                        ("loss", Json::num(r.loss as f64)),
                        ("step_time_s", Json::num(r.step_time_s)),
                    ])
                })
                .collect(),
        )
    }

    /// Render a terminal sparkline of the loss curve (for example output).
    pub fn sparkline(&self, width: usize) -> String {
        if self.records.is_empty() {
            return String::new();
        }
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let losses: Vec<f64> = self.records.iter().map(|r| r.loss as f64).collect();
        let chunk = losses.len().div_ceil(width);
        let pts: Vec<f64> = losses
            .chunks(chunk)
            .map(|c| c.iter().sum::<f64>() / c.len() as f64)
            .collect();
        let lo = pts.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = pts.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        pts.iter()
            .map(|&x| {
                let t = if hi > lo { (x - lo) / (hi - lo) } else { 0.0 };
                BARS[((t * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(losses: &[f32]) -> MetricsLog {
        let mut m = MetricsLog::new();
        for (i, &l) in losses.iter().enumerate() {
            m.push(StepRecord {
                step: i as u64,
                loss: l,
                step_time_s: 0.1,
                tokens: 64,
            });
        }
        m
    }

    #[test]
    fn tail_mean() {
        let m = log_with(&[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(m.mean_loss_tail(2).unwrap(), 1.5);
        assert_eq!(m.mean_loss_tail(100).unwrap(), 2.5);
        assert!(MetricsLog::new().mean_loss_tail(2).is_none());
    }

    #[test]
    fn throughput() {
        let m = log_with(&[1.0; 10]);
        assert!((m.tokens_per_second() - 640.0).abs() < 1e-6);
    }

    #[test]
    fn csv_roundtrip_shape(){
        let m = log_with(&[2.0, 1.0]);
        let dir = std::env::temp_dir().join("axlearn_test_metrics");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("loss.csv");
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss"));
    }

    #[test]
    fn sparkline_monotone_descent() {
        let m = log_with(&[8.0, 6.0, 4.0, 2.0, 1.0, 0.5, 0.4, 0.3]);
        let s = m.sparkline(8);
        assert_eq!(s.chars().count(), 8);
        let first = s.chars().next().unwrap();
        let last = s.chars().last().unwrap();
        assert!(first as u32 > last as u32, "{s}");
    }
}
