//! CI schedule-lint gate: run the static schedule verifier
//! (`axlearn::composer::verify`) over every mesh-rules preset target and
//! the canonical 14-point mesh sweep, print one row per target, and exit
//! nonzero on any diagnostic — so a schedule-lowering change that breaks
//! subgroup tiling, phase ordering, payload conservation, P2P
//! deadlock-freedom, or the HBM watermark fails the `bench` job instead
//! of surfacing as a runtime panic deep in a sweep.
//!
//! ```text
//! verify [--json <report_path>]
//! ```
//!
//! * `--json` — additionally write the full lint report (every target,
//!   every diagnostic) as a JSON artifact for CI upload.
//!
//! The check logic lives in `axlearn::composer::verify`; the tier-1
//! test `rust/tests/verify_suite.rs` proves each diagnostic class fires
//! on an injected corruption.

use std::path::PathBuf;
use std::process::ExitCode;

use axlearn::composer::{lint_doc, lint_presets, lint_sweep};

fn usage() -> ExitCode {
    eprintln!("usage: verify [--json <path>]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut json_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => match args.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => return usage(),
            },
            _ => return usage(),
        }
    }

    let mut rows = match lint_presets() {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("verify: materializing preset targets: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    rows.extend(lint_sweep());
    // serving targets lint through the same gate: every serve-* preset's
    // lowered schedule (TP all-reduce, MoE all-to-all pair, KV-handoff
    // P2P) must satisfy the same static checks as the trainer plans
    match axlearn::serving::lint_serve_presets() {
        Ok(serve_rows) => rows.extend(serve_rows),
        Err(e) => {
            eprintln!("verify: lowering serve presets: {e:#}");
            return ExitCode::FAILURE;
        }
    }

    let mut diagnostics = 0usize;
    for (label, report) in &rows {
        if report.is_clean() {
            println!(
                "verify: {label:<32} OK ({} entries, watermark {:.3e} B)",
                report.entries, report.watermark_bytes
            );
        } else {
            diagnostics += report.diagnostics.len();
            eprintln!("verify: {label:<32} FAILED:");
            for d in &report.diagnostics {
                eprintln!("  {d}");
            }
        }
    }

    if let Some(path) = &json_path {
        let doc = lint_doc(&rows);
        if let Err(e) = std::fs::write(path, doc.to_string() + "\n") {
            eprintln!("verify: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
        println!("verify: wrote {}", path.display());
    }

    if diagnostics > 0 {
        eprintln!(
            "verify: {diagnostics} diagnostic(s) across {} target(s)",
            rows.len()
        );
        ExitCode::FAILURE
    } else {
        println!("verify: all {} targets lint clean", rows.len());
        ExitCode::SUCCESS
    }
}
