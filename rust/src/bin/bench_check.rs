//! CI bench-regression gate: recompute the deterministic mesh sweep and
//! compare it against the committed `benches/baseline.json` — exit
//! nonzero when simulated step-time / bubble / AllToAll cost drifts
//! beyond the tolerance, so cost-model regressions fail the `bench` job
//! instead of landing silently.
//!
//! ```text
//! bench_check [--baseline <path>] [--json <bench_mesh.json>] [--tol <rel>] [--write]
//! ```
//!
//! * `--baseline` — baseline document (default `benches/baseline.json`
//!   under the repo root).
//! * `--json` — additionally verify an emitted bench artifact (the file
//!   `bench_mesh` writes under `$BENCH_JSON_DIR`) against the same
//!   recomputed points, guarding the bench's own output path.
//! * `--tol` — relative drift tolerance (default
//!   [`axlearn::composer::BASELINE_DEFAULT_TOL`]).
//! * `--write` — (re)generate the baseline from the current sweep
//!   instead of checking, for deliberate, reviewed model changes.
//!
//! The comparison logic lives in `axlearn::composer::mesh_sweep`; the
//! tier-1 test `rust/tests/bench_gate.rs` proves it catches injected
//! regressions.

use std::path::PathBuf;
use std::process::ExitCode;

use axlearn::composer::{
    compare_to_baseline, mesh_sweep_doc, mesh_sweep_points, BASELINE_DEFAULT_TOL,
};
use axlearn::util::json::Json;

fn usage() -> ExitCode {
    eprintln!("usage: bench_check [--baseline <path>] [--json <path>] [--tol <rel>] [--write]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut baseline_path: PathBuf = axlearn::repo_root().join("benches/baseline.json");
    let mut bench_json: Option<PathBuf> = None;
    let mut tol = BASELINE_DEFAULT_TOL;
    let mut write = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--baseline" => match args.next() {
                Some(p) => baseline_path = PathBuf::from(p),
                None => return usage(),
            },
            "--json" => match args.next() {
                Some(p) => bench_json = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--tol" => match args.next().and_then(|t| t.parse::<f64>().ok()) {
                Some(t) if t > 0.0 => tol = t,
                _ => return usage(),
            },
            "--write" => write = true,
            _ => return usage(),
        }
    }

    let points = mesh_sweep_points();
    if write {
        let text = mesh_sweep_doc(&points).to_string();
        if let Err(e) = std::fs::write(&baseline_path, text + "\n") {
            eprintln!("bench_check: writing {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        println!(
            "bench_check: wrote {} ({} points) — commit it with the change that moved the numbers",
            baseline_path.display(),
            points.len()
        );
        return ExitCode::SUCCESS;
    }

    let mut failed = false;
    for (label, path) in std::iter::once(("baseline", baseline_path.clone()))
        .chain(bench_json.into_iter().map(|p| ("bench artifact", p)))
    {
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bench_check: reading {label} {}: {e}", path.display());
                eprintln!("  (generate the baseline with `bench_check --write`)");
                failed = true;
                continue;
            }
        };
        let doc = match Json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench_check: parsing {label} {}: {e}", path.display());
                failed = true;
                continue;
            }
        };
        let drifts = compare_to_baseline(&points, &doc, tol);
        if drifts.is_empty() {
            println!(
                "bench_check: {label} {} OK ({} points within {:.3}% relative)",
                path.display(),
                points.len(),
                tol * 100.0
            );
        } else {
            eprintln!(
                "bench_check: {label} {} DRIFTED ({} findings):",
                path.display(),
                drifts.len()
            );
            for d in &drifts {
                eprintln!("  {d}");
            }
            eprintln!(
                "  intentional model change? regenerate with `bench_check --write` and \
                 commit the reviewed baseline diff"
            );
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
