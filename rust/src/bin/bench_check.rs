//! CI bench-regression gate: recompute the deterministic mesh sweep
//! *and* the simulator counter sweep, and compare both against the
//! committed `benches/baseline.json` — exit nonzero when simulated
//! step-time / bubble / AllToAll cost or a topology-aware flow-simulated
//! comm time (`netsim_tiered_s` / `netsim_exposed_s`, see
//! `docs/netsim.md`) drifts beyond the tolerance, or
//! when any simulator work counter (`sim_points`: collective ops,
//! reduce additions, bytes moved, steady-state allocations) changes
//! **at all**, so cost-model regressions and reintroduced per-step
//! clones fail the `bench` job instead of landing silently.  Before any
//! comparison, the canonical sweep's schedules are run through the
//! static schedule verifier (`axlearn::composer::verify`) and the gate
//! fails on any diagnostic.
//!
//! The gate also replans the auto-sharding planner's canonical cases
//! (`axlearn::composer::planner`) and compares the chosen plans, their
//! cost columns, and the exact search counters against the baseline's
//! `planner_points` section — a pruning-bound regression surfaces as a
//! worse chosen plan or a counter drift — and, in optimized builds,
//! enforces the per-case planning latency budget
//! ([`axlearn::composer::planner::PLANNER_LATENCY_BUDGET_S`]).
//!
//! The serving curve is gated the same way: the deterministic router
//! bench (`axlearn::serving::router_bench`) is recomputed, its
//! goodput-under-SLO dominance claim re-checked, and its
//! `router_points` section compared against the baseline.
//!
//! ```text
//! bench_check [--baseline <path>] [--json <bench_mesh.json>]
//!             [--sim-json <bench_sim.json>]
//!             [--planner-json <bench_planner.json>]
//!             [--router-json <bench_router.json>] [--tol <rel>] [--write]
//! ```
//!
//! * `--baseline` — baseline document (default `benches/baseline.json`
//!   under the repo root).
//! * `--json` — additionally verify an emitted bench artifact (the file
//!   `bench_mesh` writes under `$BENCH_JSON_DIR`) against the same
//!   recomputed points, guarding the bench's own output path.
//! * `--sim-json` — likewise for the `bench_sim` artifact's counter
//!   section (its wall-clock series is reported, never gated).
//! * `--planner-json` — likewise for the `bench_planner` artifact's
//!   `planner_points` section.
//! * `--router-json` — likewise for the `bench_router` artifact's
//!   `router_points` section.
//! * `--tol` — relative drift tolerance for the step-time sweep
//!   (default [`axlearn::composer::BASELINE_DEFAULT_TOL`]); the counter
//!   sweep is always compared exactly.
//! * `--write` — (re)generate the baseline (both sections) from the
//!   current sweeps instead of checking, for deliberate, reviewed model
//!   changes.
//!
//! The comparison logic lives in `axlearn::composer::mesh_sweep` and
//! `axlearn::distributed::sim_bench`; the tier-1 test
//! `rust/tests/bench_gate.rs` proves both catch injected regressions.

use std::path::PathBuf;
use std::process::ExitCode;

use axlearn::composer::planner::{
    compare_planner_to_baseline, planner_bench_points, planner_doc, PLANNER_LATENCY_BUDGET_S,
};
use axlearn::composer::{
    compare_to_baseline, lint_sweep, mesh_sweep_doc, mesh_sweep_points, BASELINE_DEFAULT_TOL,
};
use axlearn::distributed::sim_bench::{compare_sim_to_baseline, sim_counter_points, sim_doc};
use axlearn::serving::{
    compare_router_to_baseline, dominance_violations, router_bench_points, router_doc,
};
use axlearn::util::json::Json;

fn usage() -> ExitCode {
    eprintln!(
        "usage: bench_check [--baseline <path>] [--json <path>] [--sim-json <path>] \
         [--planner-json <path>] [--router-json <path>] [--tol <rel>] [--write]"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut baseline_path: PathBuf = axlearn::repo_root().join("benches/baseline.json");
    let mut bench_json: Option<PathBuf> = None;
    let mut sim_json: Option<PathBuf> = None;
    let mut planner_json: Option<PathBuf> = None;
    let mut router_json: Option<PathBuf> = None;
    let mut tol = BASELINE_DEFAULT_TOL;
    let mut write = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--baseline" => match args.next() {
                Some(p) => baseline_path = PathBuf::from(p),
                None => return usage(),
            },
            "--json" => match args.next() {
                Some(p) => bench_json = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--sim-json" => match args.next() {
                Some(p) => sim_json = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--planner-json" => match args.next() {
                Some(p) => planner_json = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--router-json" => match args.next() {
                Some(p) => router_json = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--tol" => match args.next().and_then(|t| t.parse::<f64>().ok()) {
                Some(t) if t > 0.0 => tol = t,
                _ => return usage(),
            },
            "--write" => write = true,
            _ => return usage(),
        }
    }

    // Lint the canonical sweep's schedules before comparing numbers: a
    // malformed schedule makes every downstream cost meaningless, and
    // `--write` must never bake one into the baseline.
    let lint_rows = lint_sweep();
    let lint_findings: usize = lint_rows.iter().map(|(_, r)| r.diagnostics.len()).sum();
    if lint_findings > 0 {
        eprintln!("bench_check: static schedule verifier rejected the sweep:");
        for (label, report) in &lint_rows {
            for d in &report.diagnostics {
                eprintln!("  {label}: {d}");
            }
        }
        return ExitCode::FAILURE;
    }
    println!(
        "bench_check: {} sweep schedules lint clean",
        lint_rows.len()
    );

    let points = mesh_sweep_points();
    let sim_points = sim_counter_points();
    let planner_points = planner_bench_points();
    let router_points = match router_bench_points() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bench_check: running the router bench: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    if write {
        let mut doc = mesh_sweep_doc(&points);
        let sim = sim_doc(&sim_points);
        if let (Json::Obj(map), Some(sp)) = (&mut doc, sim.get("sim_points")) {
            map.insert("sim_points".into(), sp.clone());
        }
        let planner = planner_doc(&planner_points);
        if let (Json::Obj(map), Some(pp)) = (&mut doc, planner.get("planner_points")) {
            map.insert("planner_points".into(), pp.clone());
        }
        let router = router_doc(&router_points);
        if let (Json::Obj(map), Some(rp)) = (&mut doc, router.get("router_points")) {
            map.insert("router_points".into(), rp.clone());
        }
        let text = doc.to_string();
        if let Err(e) = std::fs::write(&baseline_path, text + "\n") {
            eprintln!("bench_check: writing {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        println!(
            "bench_check: wrote {} ({} step-time points, {} counter points, \
             {} planner points, {} router points) — commit it with the change \
             that moved the numbers",
            baseline_path.display(),
            points.len(),
            sim_points.len(),
            planner_points.len(),
            router_points.len()
        );
        return ExitCode::SUCCESS;
    }

    let mut failed = false;

    // Planner latency: the ISSUE's "16384 chips in under 5 seconds"
    // acceptance bar.  Wall-clock is only meaningful in optimized
    // builds; debug builds report the numbers without gating them.
    for p in &planner_points {
        println!(
            "bench_check: planner {} -> {} (mb={}, remat={}) in {:.3}s",
            p.case, p.mesh, p.microbatches, p.remat, p.plan_wall_s
        );
        if p.plan_wall_s >= PLANNER_LATENCY_BUDGET_S {
            if cfg!(debug_assertions) {
                println!(
                    "bench_check: (debug build — {:.3}s over the {PLANNER_LATENCY_BUDGET_S}s \
                     budget is reported, not gated)",
                    p.plan_wall_s
                );
            } else {
                eprintln!(
                    "bench_check: planner case {} took {:.3}s, budget is \
                     {PLANNER_LATENCY_BUDGET_S}s",
                    p.case, p.plan_wall_s
                );
                failed = true;
            }
        }
    }

    // The serving curve's headline claim must hold before its numbers
    // are worth comparing: at the top offered loads the disaggregated
    // fleet strictly beats the single pool on goodput-under-SLO.
    let violations = dominance_violations(&router_points, 2);
    if violations.is_empty() {
        println!(
            "bench_check: router curve OK ({} points; disagg dominates goodput at the \
             top 2 loads)",
            router_points.len()
        );
    } else {
        eprintln!("bench_check: router goodput dominance violated:");
        for v in &violations {
            eprintln!("  {v}");
        }
        failed = true;
    }

    // (label, path, gate step-time sweep?, counter sweep?, planner?, router?)
    for (label, path, mesh_gate, sim_gate, planner_gate, router_gate) in
        std::iter::once(("baseline", baseline_path.clone(), true, true, true, true))
            .chain(
                bench_json.into_iter().map(|p| ("bench artifact", p, true, false, false, false)),
            )
            .chain(sim_json.into_iter().map(|p| ("sim artifact", p, false, true, false, false)))
            .chain(
                planner_json
                    .into_iter()
                    .map(|p| ("planner artifact", p, false, false, true, false)),
            )
            .chain(
                router_json
                    .into_iter()
                    .map(|p| ("router artifact", p, false, false, false, true)),
            )
    {
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bench_check: reading {label} {}: {e}", path.display());
                eprintln!("  (generate the baseline with `bench_check --write`)");
                failed = true;
                continue;
            }
        };
        let doc = match Json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench_check: parsing {label} {}: {e}", path.display());
                failed = true;
                continue;
            }
        };
        let mut drifts = Vec::new();
        if mesh_gate {
            drifts.extend(compare_to_baseline(&points, &doc, tol));
        }
        if sim_gate {
            drifts.extend(compare_sim_to_baseline(&sim_points, &doc));
        }
        if planner_gate {
            drifts.extend(compare_planner_to_baseline(&planner_points, &doc, tol));
        }
        if router_gate {
            drifts.extend(compare_router_to_baseline(&router_points, &doc, tol));
        }
        if drifts.is_empty() {
            println!(
                "bench_check: {label} {} OK ({} points within {:.3}% relative; \
                 {} counter points exact; {} planner points; {} router points)",
                path.display(),
                if mesh_gate { points.len() } else { 0 },
                tol * 100.0,
                if sim_gate { sim_points.len() } else { 0 },
                if planner_gate { planner_points.len() } else { 0 },
                if router_gate { router_points.len() } else { 0 }
            );
        } else {
            eprintln!(
                "bench_check: {label} {} DRIFTED ({} findings):",
                path.display(),
                drifts.len()
            );
            for d in &drifts {
                eprintln!("  {d}");
            }
            eprintln!(
                "  intentional model change? regenerate with `bench_check --write` and \
                 commit the reviewed baseline diff"
            );
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
