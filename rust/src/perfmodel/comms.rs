//! Collective communication cost model over hierarchical interconnects.
//!
//! Standard ring/bidirectional-ring costs: for `n` participants moving
//! `bytes` of payload over per-chip bandwidth `bw`:
//!   all-reduce      2·bytes·(n-1)/n / bw
//!   all-gather      bytes·(n-1)/n / bw
//!   reduce-scatter  bytes·(n-1)/n / bw
//!   all-to-all      bytes / bw          (full payload on the access link)
//! plus a per-hop latency term.  When a collective spans both the fast
//! domain and the slow network, the slow phase dominates (hierarchical
//! reduction: intra-domain reduce, inter-domain exchange, intra-domain
//! broadcast).

use super::chips::Interconnect;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
    Broadcast,
    P2P,
}

fn payload_factor(c: Collective, n: f64) -> f64 {
    match c {
        Collective::AllReduce => 2.0 * (n - 1.0) / n,
        Collective::AllGather | Collective::ReduceScatter => (n - 1.0) / n,
        // All-to-all-v over a switch: each rank injects bytes/(n-1) to
        // every peer, so the access link carries the full payload — the
        // ring (n-1)/n discount does not apply (routing is
        // data-dependent; no uniform 1/n share stays local).  The flow
        // simulator's single-domain run pins this factor
        // (netsim::algos::alltoall_uplink_carries_the_full_payload).
        Collective::AllToAll => 1.0,
        Collective::Broadcast => 1.0,
        Collective::P2P => 1.0,
    }
}

/// Time for a collective among `n` chips all within one fast domain.
pub fn intra_domain(c: Collective, bytes: f64, n: usize, ic: &Interconnect) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    bytes * payload_factor(c, nf) / ic.intra_bw + ic.intra_latency * nf.log2().ceil()
}

/// Time for a collective among `n_domains` groups over the slow network
/// (per-chip payload `bytes`).
pub fn inter_domain(c: Collective, bytes: f64, n_domains: usize, ic: &Interconnect) -> f64 {
    if n_domains <= 1 {
        return 0.0;
    }
    let nf = n_domains as f64;
    bytes * payload_factor(c, nf) / ic.inter_bw + ic.inter_latency * nf.log2().ceil()
}

/// Per-replica MoE token payload of one expert dispatch/combine
/// all-to-all: one `[tokens/dp, model_dim]` bf16 block, with the token
/// count clamped so a degenerate `global_batch < dp` still moves one
/// sequence per replica.
///
/// The single source of truth for the expert `tok_bytes` formula —
/// [`crate::perfmodel::estimator::estimate_step`],
/// [`crate::composer::build_schedule`], and the bench-gate sweep all
/// call it, which is what makes the "schedule prices exactly what the
/// estimator prices" assertion in `bench_mesh` span the estimator
/// instead of comparing two copies.
pub fn expert_tok_bytes(global_batch: usize, seq_len: usize, dp: usize, model_dim: u64) -> f64 {
    let dp = dp.max(1);
    ((global_batch.max(dp) * seq_len) / dp) as f64 * model_dim as f64 * 2.0
}

/// Total per-step expert-dispatch communication: 2 dispatch + 2 combine
/// all-to-alls per resident MoE layer (forward and backward), over the
/// expert subgroup.  Shared companion of [`expert_tok_bytes`].
pub fn expert_alltoall_cost(
    tok_bytes: f64,
    layers_resident: f64,
    expert: usize,
    ic: &Interconnect,
) -> f64 {
    4.0 * layers_resident * hierarchical(Collective::AllToAll, tok_bytes, expert, ic)
}

/// Hierarchical collective: `n` chips spread over domains of
/// `domain_size`.  Cost = intra phase + inter phase (+ intra broadcast for
/// all-reduce, folded into the payload factors).
pub fn hierarchical(c: Collective, bytes: f64, n: usize, ic: &Interconnect) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let within = n.min(ic.domain_size);
    let across = n.div_ceil(ic.domain_size);
    match c {
        Collective::AllReduce => {
            // reduce-scatter intra + all-reduce inter (on 1/within shard) +
            // all-gather intra
            let rs = intra_domain(Collective::ReduceScatter, bytes, within, ic);
            let ar = inter_domain(Collective::AllReduce, bytes / within as f64, across, ic);
            let ag = intra_domain(Collective::AllGather, bytes, within, ic);
            rs + ar + ag
        }
        _ => intra_domain(c, bytes, within, ic) + inter_domain(c, bytes / within as f64, across, ic),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::chips;

    fn ic() -> Interconnect {
        chips::h100().interconnect
    }

    #[test]
    fn single_chip_is_free() {
        assert_eq!(intra_domain(Collective::AllReduce, 1e9, 1, &ic()), 0.0);
        assert_eq!(hierarchical(Collective::AllReduce, 1e9, 1, &ic()), 0.0);
    }

    #[test]
    fn allreduce_is_twice_allgather_payload() {
        let n = 8;
        let ar = intra_domain(Collective::AllReduce, 1e9, n, &ic());
        let ag = intra_domain(Collective::AllGather, 1e9, n, &ic());
        assert!((ar / ag - 2.0).abs() < 0.05, "{ar} vs {ag}");
    }

    #[test]
    fn cost_scales_with_bytes() {
        let a = intra_domain(Collective::AllReduce, 1e9, 8, &ic());
        let b = intra_domain(Collective::AllReduce, 2e9, 8, &ic());
        assert!(b > a * 1.9 && b < a * 2.1);
    }

    #[test]
    fn crossing_domains_is_much_slower() {
        let ic = ic();
        let within = hierarchical(Collective::AllReduce, 1e9, 8, &ic);
        let across = hierarchical(Collective::AllReduce, 1e9, 64, &ic);
        assert!(
            across > within * 3.0,
            "within {within} across {across}"
        );
    }

    #[test]
    fn payload_factor_saturates() {
        // (n-1)/n -> 1: doubling n at large n barely changes payload time
        let a = intra_domain(Collective::AllGather, 1e9, 512, &chips::tpu_v5p().interconnect);
        let b = intra_domain(Collective::AllGather, 1e9, 1024, &chips::tpu_v5p().interconnect);
        assert!((b - a) / a < 0.02);
    }

    #[test]
    fn alltoall_prices_the_full_payload() {
        // Regression: all-to-all used the ring (n-1)/n discount, which
        // undercharges switch-based all-to-all-v where the access link
        // carries the whole payload.  Pin factor 1.0 against the ring
        // collectives, which keep their discount.
        let n = 8;
        let bytes = 1e9;
        let ic = ic();
        let a2a = intra_domain(Collective::AllToAll, bytes, n, &ic);
        let ag = intra_domain(Collective::AllGather, bytes, n, &ic);
        let lat = ic.intra_latency * (n as f64).log2().ceil();
        assert_eq!((a2a - lat) * ic.intra_bw, bytes, "all-to-all factor must be exactly 1");
        assert!(
            ((a2a - lat) / (ag - lat) - n as f64 / (n as f64 - 1.0)).abs() < 1e-12,
            "all-gather keeps the ring discount"
        );
    }

    #[test]
    fn latency_term_present_for_tiny_payloads() {
        let t = intra_domain(Collective::AllReduce, 8.0, 8, &ic());
        assert!(t >= ic().intra_latency * 3.0);
    }
}
