//! Parallelism strategies: the mesh axes of §4.2 (data, fsdp, tensor,
//! pipeline, expert) with validation and per-axis communication volumes.

use anyhow::{bail, Result};

/// A concrete parallelism strategy over `total_chips()` devices.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Strategy {
    /// Pure data parallelism (replicated parameters).
    pub data: usize,
    /// Fully-sharded data parallelism (ZeRO-3 style).
    pub fsdp: usize,
    /// Tensor model parallelism.
    pub tensor: usize,
    /// Pipeline stages.
    pub pipeline: usize,
    /// Expert parallelism (MoE).
    pub expert: usize,
    /// Microbatches per step (pipeline scheduling).
    pub microbatches: usize,
}

impl Default for Strategy {
    fn default() -> Self {
        Strategy {
            data: 1,
            fsdp: 1,
            tensor: 1,
            pipeline: 1,
            expert: 1,
            microbatches: 1,
        }
    }
}

impl Strategy {
    pub fn fsdp_only(n: usize) -> Self {
        Strategy {
            fsdp: n,
            ..Default::default()
        }
    }

    pub fn total_chips(&self) -> usize {
        self.data * self.fsdp * self.tensor * self.pipeline * self.expert
    }

    pub fn validate(&self, global_batch: usize, num_layers: usize) -> Result<()> {
        for (name, v) in [
            ("data", self.data),
            ("fsdp", self.fsdp),
            ("tensor", self.tensor),
            ("pipeline", self.pipeline),
            ("expert", self.expert),
            ("microbatches", self.microbatches),
        ] {
            if v == 0 {
                bail!("{name} axis must be >= 1");
            }
        }
        let dp = self.data * self.fsdp;
        // Batch shards over the data axes; when sequences are scarcer than
        // shards, sequence/context parallelism splits tokens instead
        // (paper §4.2 lists sequence parallelism as a native strategy) —
        // so the requirement is token-divisibility, checked by the caller
        // against batch*seq. Here we sanity-check only degenerate zeros.
        if global_batch == 0 || dp == 0 {
            bail!("global batch {global_batch} / dp degree {dp} must be positive");
        }
        if self.pipeline > 1 {
            if num_layers % self.pipeline != 0 {
                bail!(
                    "{num_layers} layers not divisible into {} pipeline stages",
                    self.pipeline
                );
            }
            if self.microbatches < self.pipeline {
                bail!(
                    "pipeline with {} stages needs >= that many microbatches (got {})",
                    self.pipeline,
                    self.microbatches
                );
            }
        }
        Ok(())
    }

    /// Pipeline bubble fraction for a GPipe/1F1B schedule.
    pub fn pipeline_bubble(&self) -> f64 {
        if self.pipeline <= 1 {
            return 0.0;
        }
        let p = self.pipeline as f64;
        let m = self.microbatches as f64;
        (p - 1.0) / (m + p - 1.0)
    }

    /// Resolve a mesh spec with a single -1 wildcard against a chip count
    /// (the composer's `mesh(data=-1, fsdp=256)` idiom).
    pub fn from_mesh(shape: &[i64], names: &[String], total: usize) -> Result<Strategy> {
        if shape.len() != names.len() {
            bail!("mesh rank mismatch: {shape:?} vs {names:?}");
        }
        let known: i64 = shape.iter().filter(|&&d| d > 0).product();
        let wildcards = shape.iter().filter(|&&d| d < 0).count();
        if wildcards > 1 {
            bail!("at most one -1 mesh dim allowed: {shape:?}");
        }
        if known <= 0 || total as i64 % known != 0 {
            bail!("mesh {shape:?} does not divide {total} chips");
        }
        let fill = if wildcards == 1 { total as i64 / known } else { 1 };
        let resolved_total: i64 = known * fill;
        if resolved_total != total as i64 {
            bail!(
                "mesh {shape:?} resolves to {resolved_total} chips but target has {total}"
            );
        }
        let mut s = Strategy::default();
        for (dim, name) in shape.iter().zip(names) {
            let d = if *dim < 0 { fill as usize } else { *dim as usize };
            match name.as_str() {
                "data" => s.data *= d,
                "fsdp" => s.fsdp *= d,
                "model" | "tensor" => s.tensor *= d,
                "pipeline" => s.pipeline *= d,
                "expert" => s.expert *= d,
                other => bail!("unknown mesh axis {other:?}"),
            }
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_chips_product() {
        let s = Strategy {
            data: 2,
            fsdp: 4,
            tensor: 8,
            pipeline: 2,
            expert: 1,
            microbatches: 8,
        };
        assert_eq!(s.total_chips(), 128);
    }

    #[test]
    fn validate_batch_positive() {
        let s = Strategy::fsdp_only(64);
        assert!(s.validate(1024, 32).is_ok());
        assert!(s.validate(0, 32).is_err());
    }

    #[test]
    fn validate_pipeline_constraints() {
        let mut s = Strategy {
            pipeline: 4,
            microbatches: 2,
            ..Default::default()
        };
        assert!(s.validate(64, 32).is_err()); // microbatches < stages
        s.microbatches = 8;
        assert!(s.validate(64, 32).is_ok());
        assert!(s.validate(64, 30).is_err()); // layers not divisible
    }

    #[test]
    fn bubble_shrinks_with_microbatches() {
        let mut s = Strategy {
            pipeline: 4,
            microbatches: 4,
            ..Default::default()
        };
        let b1 = s.pipeline_bubble();
        s.microbatches = 32;
        let b2 = s.pipeline_bubble();
        assert!(b2 < b1);
        assert!(b1 < 0.5);
        assert_eq!(Strategy::default().pipeline_bubble(), 0.0);
    }

    #[test]
    fn from_mesh_wildcard() {
        let s = Strategy::from_mesh(
            &[-1, 8],
            &["fsdp".into(), "model".into()],
            256,
        )
        .unwrap();
        assert_eq!(s.fsdp, 32);
        assert_eq!(s.tensor, 8);
        assert_eq!(s.total_chips(), 256);
    }

    #[test]
    fn from_mesh_rejects_bad_fit() {
        assert!(Strategy::from_mesh(&[3, 8], &["fsdp".into(), "model".into()], 256).is_err());
        assert!(Strategy::from_mesh(&[-1, -1], &["fsdp".into(), "model".into()], 256).is_err());
        assert!(Strategy::from_mesh(&[4, 8], &["fsdp".into(), "model".into()], 256).is_err());
    }

    #[test]
    fn from_mesh_exact() {
        let s = Strategy::from_mesh(&[4, 2], &["fsdp".into(), "model".into()], 8).unwrap();
        assert_eq!((s.fsdp, s.tensor), (4, 2));
    }
}
