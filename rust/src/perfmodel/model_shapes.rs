//! Transformer shape math: parameters, FLOPs, activation/KV bytes.
//!
//! Conventions follow the standard accounting (Kaplan et al. / PaLM
//! appendix): train FLOPs/token ~= 6N + 12·L·s·d_attn, forward-only ~= 2N +
//! 4·L·s·d_attn (score+value terms with causal halving applied).

/// Dense transformer shape (Llama-style: SwiGLU FFN, tied or untied head).
#[derive(Clone, Debug)]
pub struct TransformerShape {
    pub name: String,
    pub vocab: u64,
    pub model_dim: u64,
    pub num_layers: u64,
    pub num_heads: u64,
    pub head_dim: u64,
    /// FFN hidden dim (per expert when MoE).
    pub ffn_dim: u64,
    /// KV heads (GQA); == num_heads when MHA.
    pub kv_heads: u64,
    /// MoE experts (1 = dense) and active experts per token.
    pub num_experts: u64,
    pub active_experts: u64,
    pub tied_lm_head: bool,
}

impl TransformerShape {
    /// Llama2-7B (Table 3 row 1): d=4096, L=32, 32 heads, ffn 11008.
    pub fn llama2_7b() -> Self {
        TransformerShape {
            name: "Llama2-7B".into(),
            vocab: 32000,
            model_dim: 4096,
            num_layers: 32,
            num_heads: 32,
            head_dim: 128,
            ffn_dim: 11008,
            kv_heads: 32,
            num_experts: 1,
            active_experts: 1,
            tied_lm_head: false,
        }
    }

    /// Llama2-70B (Table 3 row 2): d=8192, L=80, 64 heads GQA-8, ffn 28672.
    pub fn llama2_70b() -> Self {
        TransformerShape {
            name: "Llama2-70B".into(),
            vocab: 32000,
            model_dim: 8192,
            num_layers: 80,
            num_heads: 64,
            head_dim: 128,
            ffn_dim: 28672,
            kv_heads: 8,
            num_experts: 1,
            active_experts: 1,
            tied_lm_head: false,
        }
    }

    /// Figure 4 "Model A": 70B-class dense, 4k context.
    pub fn model_a_70b() -> Self {
        let mut s = Self::llama2_70b();
        s.name = "ModelA-70B".into();
        s
    }

    /// Figure 4 "Model B": 150B-class dense, 8k context.
    pub fn model_b_150b() -> Self {
        TransformerShape {
            name: "ModelB-150B".into(),
            vocab: 100_000,
            model_dim: 10240,
            num_layers: 100,
            num_heads: 80,
            head_dim: 128,
            ffn_dim: 35840,
            kv_heads: 8,
            num_experts: 1,
            active_experts: 1,
            tied_lm_head: false,
        }
    }

    /// Our local presets (mirrors python/compile/configs.PRESETS).
    pub fn preset(name: &str) -> Option<Self> {
        let (vocab, d, l, h, hd, f) = match name {
            "tiny" => (256, 64, 2, 4, 16, 192),
            "small" | "serve" => (2048, 256, 4, 4, 64, 704),
            "base100m" => (8192, 768, 12, 12, 64, 2048),
            _ => return None,
        };
        Some(TransformerShape {
            name: name.into(),
            vocab,
            model_dim: d,
            num_layers: l,
            num_heads: h,
            head_dim: hd,
            ffn_dim: f,
            kv_heads: h,
            num_experts: 1,
            active_experts: 1,
            tied_lm_head: true,
        })
    }

    pub fn attn_inner(&self) -> u64 {
        self.num_heads * self.head_dim
    }

    /// Total parameter count.
    pub fn params(&self) -> u64 {
        let d = self.model_dim;
        let inner = self.attn_inner();
        let kv_inner = self.kv_heads * self.head_dim;
        let attn = d * inner + 2 * d * kv_inner + inner * d; // q,k,v,o
        let ffn = 3 * d * self.ffn_dim * self.num_experts; // swiglu x experts
        let router = if self.num_experts > 1 { d * self.num_experts } else { 0 };
        let norms = 2 * d;
        let emb = self.vocab * d;
        let head = if self.tied_lm_head { 0 } else { self.vocab * d };
        emb + head + self.num_layers * (attn + ffn + router + norms) + d
    }

    /// Parameters active per token (MoE: only top-k experts count).
    pub fn active_params(&self) -> u64 {
        if self.num_experts <= 1 {
            return self.params();
        }
        let dense_ffn = 3 * self.model_dim * self.ffn_dim;
        self.params() - self.num_layers * dense_ffn * (self.num_experts - self.active_experts)
    }

    /// Training FLOPs per token at sequence length `seq` (6N + attention).
    pub fn train_flops_per_token(&self, seq: u64) -> f64 {
        let n = self.active_params() as f64;
        // causal attention: 12·L·s·(heads·head_dim) with the 1/2 causal
        // factor already applied (6·L·s·inner fwd+bwd)
        let attn = 6.0 * self.num_layers as f64 * seq as f64 * self.attn_inner() as f64;
        6.0 * n + attn
    }

    /// Forward-only FLOPs per token (serving).
    pub fn fwd_flops_per_token(&self, context: u64) -> f64 {
        let n = self.active_params() as f64;
        let attn = 2.0 * self.num_layers as f64 * context as f64 * self.attn_inner() as f64;
        2.0 * n + attn
    }

    /// Bytes of parameters at a given dtype width.
    pub fn param_bytes(&self, bytes_per_param: f64) -> f64 {
        self.params() as f64 * bytes_per_param
    }

    /// Optimizer state bytes (AdamW: m+v in f32, master weights f32).
    pub fn optimizer_bytes(&self) -> f64 {
        self.params() as f64 * 12.0
    }

    /// Activation bytes per token per layer with NO remat (bf16), the
    /// standard ~34·d + 5·s·heads estimate reduced to its dominant terms.
    pub fn act_bytes_per_token_layer(&self, seq: u64) -> f64 {
        let d = self.model_dim as f64;
        // qkv+attn-out+2 norms+ffn intermediates (swiglu: 3 tensors of
        // ffn_dim) in bf16 + attention probabilities term (flash removes
        // the s^2 term; we charge the flash streaming footprint instead).
        let dense = (10.0 * d + 3.0 * self.ffn_dim as f64) * 2.0;
        let flash_lse = self.num_heads as f64 * 4.0; // lse per token
        let _ = seq;
        dense + flash_lse
    }

    /// KV-cache bytes per token (bf16 K+V across layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.num_layers * self.kv_heads * self.head_dim) as f64 * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count() {
        let p = TransformerShape::llama2_7b().params();
        assert!(
            (6.5e9..7.3e9).contains(&(p as f64)),
            "7B params = {p}"
        );
    }

    #[test]
    fn llama2_70b_param_count() {
        let p = TransformerShape::llama2_70b().params();
        assert!(
            (6.6e10..7.2e10).contains(&(p as f64)),
            "70B params = {p}"
        );
    }

    #[test]
    fn model_b_is_about_150b() {
        let p = TransformerShape::model_b_150b().params();
        assert!((1.3e11..1.7e11).contains(&(p as f64)), "150B params = {p}");
    }

    #[test]
    fn presets_match_python_scale() {
        assert!((TransformerShape::preset("base100m").unwrap().params() as f64 - 1.0e8).abs() < 3e7);
        let tiny = TransformerShape::preset("tiny").unwrap().params();
        assert!((1e5..2e5).contains(&(tiny as f64)), "tiny = {tiny}");
    }

    #[test]
    fn train_flops_dominated_by_6n() {
        let s = TransformerShape::llama2_7b();
        let f = s.train_flops_per_token(4096);
        let six_n = 6.0 * s.params() as f64;
        assert!(f > six_n && f < 1.5 * six_n);
    }

    #[test]
    fn moe_active_params_lower() {
        let mut s = TransformerShape::preset("small").unwrap();
        s.num_experts = 8;
        s.active_experts = 2;
        assert!(s.active_params() < s.params());
        assert!(s.active_params() > s.params() / 8);
    }

    #[test]
    fn kv_bytes_gqa_smaller_than_mha() {
        let mha = TransformerShape::llama2_7b().kv_bytes_per_token();
        let gqa = TransformerShape::llama2_70b().kv_bytes_per_token();
        // 70B has 2.5x layers but 1/8 kv heads at same head_dim
        assert!(gqa < mha * 2.0);
    }
}
