//! Accelerator spec sheets.  All figures are public vendor numbers; where
//! ranges exist we note the choice.  The estimator only ever uses *ratios*
//! of these numbers (MFU, comm/compute balance), which is what makes the
//! simulation credible for reproducing the paper's orderings.

/// Interconnect description: a fast intra-domain fabric (NVLink island /
/// ICI slice / NeuronLink) and a slower inter-domain network (IB/EFA/DCN).
#[derive(Clone, Debug)]
pub struct Interconnect {
    /// Chips per fast domain (NVLink island = 8, v5p slice <= 8960, ...).
    pub domain_size: usize,
    /// Per-chip bidirectional bandwidth within the fast domain (bytes/s).
    pub intra_bw: f64,
    /// Per-chip bandwidth across domains (bytes/s).
    pub inter_bw: f64,
    /// Per-collective base latency within a domain (seconds).
    pub intra_latency: f64,
    /// Per-collective base latency across domains (seconds).
    pub inter_latency: f64,
}

/// One accelerator chip.
#[derive(Clone, Debug)]
pub struct ChipSpec {
    pub name: &'static str,
    /// Dense BF16 peak (FLOP/s).
    pub peak_flops_bf16: f64,
    /// Peak with INT8/FP8 quantized matmuls (FLOP/s).
    pub peak_flops_8bit: f64,
    /// HBM capacity (bytes).
    pub hbm_bytes: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// Host-offload (PCIe/DMA) bandwidth (bytes/s) for activation/optimizer
    /// offload; 0 when the platform does not support it well.
    pub host_bw: f64,
    pub interconnect: Interconnect,
}

/// NVIDIA H100 SXM (DGX/P5): 989 TFLOPs dense BF16, 80 GB HBM3 @ 3.35
/// TB/s, NVLink4 900 GB/s, inter-node 8x400 Gbps EFA/IB per 8-GPU node.
pub fn h100() -> ChipSpec {
    ChipSpec {
        name: "H100",
        peak_flops_bf16: 989e12,
        peak_flops_8bit: 1979e12,
        hbm_bytes: 80e9,
        hbm_bw: 3.35e12,
        host_bw: 55e9, // PCIe gen5 x16 effective
        interconnect: Interconnect {
            domain_size: 8,
            intra_bw: 900e9,
            inter_bw: 50e9, // 400 Gb/s per GPU on P5
            intra_latency: 5e-6,
            inter_latency: 20e-6,
        },
    }
}

/// Google TPU v5p: 459 TFLOPs BF16, 95 GB HBM @ 2.77 TB/s, ICI ~600 GB/s
/// per chip (3D torus, 4800 Gbps aggregate), slices to 8960 chips; DCN
/// across slices.
pub fn tpu_v5p() -> ChipSpec {
    ChipSpec {
        name: "TPUv5p",
        peak_flops_bf16: 459e12,
        peak_flops_8bit: 918e12,
        hbm_bytes: 95e9,
        hbm_bw: 2.77e12,
        host_bw: 40e9,
        interconnect: Interconnect {
            domain_size: 8960,
            intra_bw: 600e9,
            inter_bw: 25e9, // DCN
            intra_latency: 2e-6,
            inter_latency: 50e-6,
        },
    }
}

/// Google TPU v5e: 197 TFLOPs BF16, 16 GB HBM @ 819 GB/s, ICI 400 GB/s,
/// slices of 256; DCN across slices.  (Appendix A target.)
pub fn tpu_v5e() -> ChipSpec {
    ChipSpec {
        name: "TPUv5e",
        peak_flops_bf16: 197e12,
        peak_flops_8bit: 394e12,
        hbm_bytes: 16e9,
        hbm_bw: 819e9,
        host_bw: 30e9,
        interconnect: Interconnect {
            domain_size: 256,
            intra_bw: 400e9,
            inter_bw: 12.5e9,
            intra_latency: 2e-6,
            inter_latency: 50e-6,
        },
    }
}

/// Google TPU v6e (Trillium): ~918 TFLOPs BF16, 32 GB HBM @ 1.64 TB/s.
/// (Table 4's 70B inference host.)
pub fn tpu_v6e() -> ChipSpec {
    ChipSpec {
        name: "TPUv6e",
        peak_flops_bf16: 918e12,
        peak_flops_8bit: 1836e12,
        hbm_bytes: 32e9,
        hbm_bw: 1.64e12,
        host_bw: 40e9,
        interconnect: Interconnect {
            domain_size: 256,
            intra_bw: 800e9,
            inter_bw: 25e9,
            intra_latency: 2e-6,
            inter_latency: 50e-6,
        },
    }
}

/// AWS Trainium2: ~650 TFLOPs dense BF16 (1.3 PFLOPs FP8), 96 GB HBM3 @
/// ~2.9 TB/s, NeuronLink within a 16-chip trn2 instance, EFA across.
pub fn trainium2() -> ChipSpec {
    ChipSpec {
        name: "Trainium2",
        peak_flops_bf16: 650e12,
        peak_flops_8bit: 1300e12,
        hbm_bytes: 96e9,
        hbm_bw: 2.9e12,
        host_bw: 30e9,
        interconnect: Interconnect {
            domain_size: 16,
            intra_bw: 185e9, // NeuronLink-v3 per chip
            inter_bw: 25e9,  // EFA
            intra_latency: 5e-6,
            inter_latency: 30e-6,
        },
    }
}

/// Lookup by the instance-type prefixes used in mesh rules.  A
/// `planner-` prefix (the auto-sharding planner's dynamic rule kind,
/// e.g. `planner-gpu-H100-4096`) is transparent: the planned instance
/// resolves to the same chip as the hand-written preset would.
pub fn by_instance_type(instance_type: &str) -> Option<ChipSpec> {
    let t = instance_type.to_ascii_lowercase();
    let t = t.strip_prefix("planner-").unwrap_or(&t);
    if t.starts_with("gpu-h100") {
        Some(h100())
    } else if t.starts_with("tpu-v5p") {
        Some(tpu_v5p())
    } else if t.starts_with("tpu-v5e") {
        Some(tpu_v5e())
    } else if t.starts_with("tpu-v6e") {
        Some(tpu_v6e())
    } else if t.starts_with("trn2") {
        Some(trainium2())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_instance_type() {
        assert_eq!(by_instance_type("gpu-H100-32").unwrap().name, "H100");
        assert_eq!(by_instance_type("tpu-v5p-512").unwrap().name, "TPUv5p");
        assert_eq!(by_instance_type("trn2-16xlarge").unwrap().name, "Trainium2");
        assert!(by_instance_type("cpu-local").is_none());
    }

    #[test]
    fn specs_are_sane() {
        for chip in [h100(), tpu_v5p(), tpu_v5e(), tpu_v6e(), trainium2()] {
            assert!(chip.peak_flops_bf16 > 1e14, "{}", chip.name);
            assert!(chip.peak_flops_8bit >= chip.peak_flops_bf16);
            assert!(chip.hbm_bytes > 1e10);
            assert!(chip.hbm_bw > 1e11);
            assert!(chip.interconnect.intra_bw > chip.interconnect.inter_bw);
            assert!(chip.interconnect.domain_size >= 8);
        }
    }

    #[test]
    fn h100_arithmetic_intensity_exceeds_tpu_v5e() {
        // sanity of relative spec sheet: flops/byte ordering
        let h = h100();
        let e = tpu_v5e();
        assert!(h.peak_flops_bf16 / h.hbm_bw > e.peak_flops_bf16 / e.hbm_bw * 0.5);
    }
}
