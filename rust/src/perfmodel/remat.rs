//! Rematerialization (activation checkpointing) cost semantics.
//!
//! The paper repeatedly hinges on remat *granularity* (§7.2: "PyTorch FSDP
//! ... checkpoints occur at the decoder block level, meaning that
//! activations within a decoder layer must be either fully recomputed or
//! fully saved.  On the other hand, AXLearn can save only the most
//! expensive operations").  This module prices that difference: each
//! policy keeps a fraction of activation bytes resident and pays a
//! fraction of the forward FLOPs again in the backward pass.

/// A remat policy with its cost coefficients.
#[derive(Clone, Debug, PartialEq)]
pub struct RematCost {
    pub policy: &'static str,
    /// Fraction of per-layer activation bytes kept in HBM.
    pub act_bytes_kept: f64,
    /// Fraction of forward FLOPs recomputed during backward.
    pub recompute_frac: f64,
    /// Bytes offloaded to host per activation byte (0 unless offloading).
    pub offload_frac: f64,
}

/// Policy table.  `save_qkvo` and `save_linear` are the fine-grained
/// "tagged remat point" policies only AXLearn-style systems can express;
/// `full`/`none` is all a block-granularity system offers.
pub fn cost(policy: &str) -> RematCost {
    match policy {
        // keep everything: no recompute, full activation residency
        "none" => RematCost {
            policy: "none",
            act_bytes_kept: 1.0,
            recompute_frac: 0.0,
            offload_frac: 0.0,
        },
        // checkpoint whole blocks: only block inputs kept, ~full fwd replay
        "full" => RematCost {
            policy: "full",
            act_bytes_kept: 0.08,
            recompute_frac: 1.0,
            offload_frac: 0.0,
        },
        // save q/k/v/o projections + block inputs; recompute the cheap rest
        "save_qkvo" => RematCost {
            policy: "save_qkvo",
            act_bytes_kept: 0.45,
            recompute_frac: 0.35,
            offload_frac: 0.0,
        },
        // save every linear-layer output (the most expensive ops), cheap
        // elementwise/norm recompute only
        "save_linear" => RematCost {
            policy: "save_linear",
            act_bytes_kept: 0.60,
            recompute_frac: 0.15,
            offload_frac: 0.0,
        },
        // offload dot-product activations to host memory (v5e rule in
        // Appendix A): low residency, low recompute, but host-DMA traffic
        "offload_dots" => RematCost {
            policy: "offload_dots",
            act_bytes_kept: 0.15,
            recompute_frac: 0.10,
            offload_frac: 0.55,
        },
        other => panic!("unknown remat policy {other:?}"),
    }
}

/// Approximate runtime penalty of a policy: recompute plus the
/// (partially hidden) host-DMA cost of offloading.  Used to order
/// candidates in [`best_fitting_policy`].
pub fn cost_key(c: &RematCost) -> f64 {
    c.recompute_frac + 0.5 * c.offload_frac
}

/// Pick the cheapest policy that fits an HBM budget, given per-layer
/// activation bytes and total layers.  This is the tuning loop an AXLearn
/// user does by hand via mesh rules, automated for the Table-3 harness.
pub fn best_fitting_policy(
    allowed: &[&str],
    act_bytes_full: f64,
    other_bytes: f64,
    hbm_budget: f64,
) -> Option<RematCost> {
    let mut candidates: Vec<RematCost> = allowed.iter().map(|p| cost(p)).collect();
    // prefer the least runtime penalty (recompute + exposed offload DMA)
    candidates.sort_by(|a, b| cost_key(a).partial_cmp(&cost_key(b)).unwrap());
    candidates
        .into_iter()
        .find(|c| other_bytes + act_bytes_full * c.act_bytes_kept <= hbm_budget)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_total_over_registry_policies() {
        for p in crate::config::modifier::REMAT_POLICIES {
            let c = cost(p);
            assert!((0.0..=1.0).contains(&c.act_bytes_kept));
            assert!((0.0..=1.0).contains(&c.recompute_frac));
        }
    }

    #[test]
    fn finer_granularity_means_less_recompute_than_full() {
        assert!(cost("save_linear").recompute_frac < cost("full").recompute_frac);
        assert!(cost("save_qkvo").recompute_frac < cost("full").recompute_frac);
    }

    #[test]
    fn memory_compute_tradeoff_is_monotone() {
        // more bytes kept => less recompute, across the non-offload policies
        let mut cs: Vec<_> = ["none", "save_linear", "save_qkvo", "full"]
            .iter()
            .map(|p| cost(p))
            .collect();
        cs.sort_by(|a, b| a.act_bytes_kept.partial_cmp(&b.act_bytes_kept).unwrap());
        for w in cs.windows(2) {
            assert!(w[0].recompute_frac >= w[1].recompute_frac, "{w:?}");
        }
    }

    #[test]
    fn best_fitting_prefers_no_recompute_when_memory_allows() {
        let c = best_fitting_policy(&["none", "full"], 1e9, 1e9, 10e9).unwrap();
        assert_eq!(c.policy, "none");
    }

    #[test]
    fn best_fitting_falls_back_under_pressure() {
        let c = best_fitting_policy(&["none", "save_linear", "full"], 10e9, 5e9, 7e9).unwrap();
        assert_eq!(c.policy, "full");
    }

    #[test]
    fn best_fitting_none_when_nothing_fits() {
        assert!(best_fitting_policy(&["none"], 10e9, 50e9, 7e9).is_none());
    }

    #[test]
    #[should_panic(expected = "unknown remat policy")]
    fn unknown_policy_panics() {
        cost("bogus");
    }
}
