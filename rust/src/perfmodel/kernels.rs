//! Structural analysis of the L1 Pallas kernel (VMEM footprint, MXU
//! utilization estimate).
//!
//! `interpret=True` gives CPU-numpy timings which say nothing about TPU
//! performance, so — per the repro harness contract — the kernel's TPU
//! efficiency is estimated *structurally* from its BlockSpec: how much
//! VMEM each grid cell touches, how many MXU passes its dots make, and
//! the HBM↔VMEM traffic the schedule implies.  Results are recorded in
//! EXPERIMENTS.md §Perf and drive block-size selection.

/// TPU core model constants (v5p-class core).
pub const VMEM_BYTES: f64 = 16.0 * 1024.0 * 1024.0; // ~16 MiB/core usable
pub const MXU_DIM: u64 = 128; // 128x128 systolic array
pub const HBM_BW: f64 = 2.77e12; // bytes/s (v5p)
pub const MXU_FLOPS: f64 = 459e12; // bf16 peak (v5p)

/// One flash-attention kernel configuration.
#[derive(Clone, Debug)]
pub struct FlashConfig {
    pub block_q: u64,
    pub block_k: u64,
    pub head_dim: u64,
    pub q_len: u64,
    pub kv_len: u64,
    /// bytes per element of q/k/v (2 = bf16)
    pub elem_bytes: f64,
}

/// Structural analysis result for one grid cell and the whole kernel.
#[derive(Clone, Debug)]
pub struct KernelAnalysis {
    /// VMEM resident bytes per grid cell (q block + k/v blocks + acc).
    pub vmem_bytes: f64,
    pub fits_vmem: bool,
    /// Fraction of each MXU pass that does useful work (padding waste).
    pub mxu_utilization: f64,
    /// HBM bytes moved per (batch*head) row of the grid.
    pub hbm_bytes_per_row: f64,
    /// Arithmetic intensity (flops / HBM byte).
    pub arithmetic_intensity: f64,
    /// Roofline-limited efficiency (min(1, AI / machine balance)).
    pub roofline_efficiency: f64,
}

impl FlashConfig {
    pub fn analyze(&self) -> KernelAnalysis {
        let d = self.head_dim as f64;
        let bq = self.block_q as f64;
        let bk = self.block_k as f64;

        // VMEM per grid cell: q block, K/V, f32 accumulator + m/l carries,
        // out block.  When the whole K/V for the (batch,head) row fits in
        // VMEM (which is what the kernel's BlockSpec requests), keep it
        // resident and read it from HBM once; otherwise stream
        // double-buffered block_k tiles and re-read per q-block.
        let q_bytes = bq * d * self.elem_bytes;
        let kv_resident_bytes = 2.0 * self.kv_len as f64 * d * self.elem_bytes;
        let acc_bytes = bq * d * 4.0 + 2.0 * bq * 4.0;
        let out_bytes = bq * d * self.elem_bytes;
        let fixed = q_bytes + acc_bytes + out_bytes;
        let kv_fits = fixed + kv_resident_bytes <= VMEM_BYTES;
        let kv_bytes = if kv_fits {
            kv_resident_bytes
        } else {
            2.0 * bk * d * self.elem_bytes * 2.0 // double-buffered tiles
        };
        let vmem = fixed + kv_bytes;

        // MXU utilization: each dot is (bq x d) @ (d x bk); the systolic
        // array processes MXU_DIM-sized tiles, so partial tiles waste
        // cycles on padding.
        let util_dim = |n: u64| {
            let tiles = n.div_ceil(MXU_DIM);
            n as f64 / (tiles * MXU_DIM) as f64
        };
        let mxu_util = util_dim(self.block_q) * util_dim(self.head_dim).max(util_dim(self.block_k));

        // HBM traffic per (batch*head): Q and O once; K/V once when
        // VMEM-resident, once per q-block pass when streamed.
        let n_qblocks = (self.q_len as f64 / bq).ceil();
        let q_traffic = self.q_len as f64 * d * self.elem_bytes;
        let kv_passes = if kv_fits { 1.0 } else { n_qblocks };
        let kv_traffic = kv_passes * self.kv_len as f64 * d * self.elem_bytes * 2.0;
        let o_traffic = self.q_len as f64 * d * self.elem_bytes;
        let hbm = q_traffic + kv_traffic + o_traffic;

        // flops per row: 2 dots of 2*bq*bk*d per (q,k) block pair, causal
        // halves the pairs.
        let flops = 2.0 * 2.0 * self.q_len as f64 * self.kv_len as f64 * d * 0.5;
        let ai = flops / hbm;
        let machine_balance = MXU_FLOPS / HBM_BW;
        let roofline = (ai / machine_balance).min(1.0);

        KernelAnalysis {
            vmem_bytes: vmem,
            fits_vmem: vmem <= VMEM_BYTES,
            mxu_utilization: mxu_util,
            hbm_bytes_per_row: hbm,
            arithmetic_intensity: ai,
            roofline_efficiency: roofline,
        }
    }
}

/// Sweep block sizes and return (block_q, block_k) maximizing estimated
/// efficiency subject to the VMEM budget — the §Perf L1 tuning loop.
pub fn best_blocks(q_len: u64, kv_len: u64, head_dim: u64) -> (u64, u64, KernelAnalysis) {
    let candidates = [64u64, 128, 256, 512];
    let mut best = None;
    for &bq in &candidates {
        for &bk in &candidates {
            if bq > q_len.max(64) || bk > kv_len.max(64) {
                continue;
            }
            let cfg = FlashConfig {
                block_q: bq,
                block_k: bk,
                head_dim,
                q_len,
                kv_len,
                elem_bytes: 2.0,
            };
            let a = cfg.analyze();
            if !a.fits_vmem {
                continue;
            }
            let score = a.mxu_utilization * a.roofline_efficiency
                / (1.0 + a.hbm_bytes_per_row / 1e9);
            match &best {
                None => best = Some((bq, bk, a, score)),
                Some((_, _, _, s)) if score > *s => best = Some((bq, bk, a, score)),
                _ => {}
            }
        }
    }
    let (bq, bk, a, _) = best.expect("some block configuration fits VMEM");
    (bq, bk, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(bq: u64, bk: u64) -> FlashConfig {
        FlashConfig {
            block_q: bq,
            block_k: bk,
            head_dim: 128,
            q_len: 4096,
            kv_len: 4096,
            elem_bytes: 2.0,
        }
    }

    #[test]
    fn default_blocks_fit_vmem() {
        let a = cfg(128, 128).analyze();
        assert!(a.fits_vmem, "vmem = {:.2} MiB", a.vmem_bytes / 1048576.0);
        assert!(a.vmem_bytes > 0.0);
    }

    #[test]
    fn huge_blocks_blow_vmem() {
        let a = FlashConfig {
            block_q: 8192,
            block_k: 8192,
            head_dim: 256,
            q_len: 8192,
            kv_len: 8192,
            elem_bytes: 4.0,
        }
        .analyze();
        assert!(!a.fits_vmem);
    }

    #[test]
    fn mxu_aligned_blocks_have_full_utilization() {
        let a = cfg(128, 128).analyze();
        assert!((a.mxu_utilization - 1.0).abs() < 1e-9);
        let b = cfg(96, 128).analyze();
        assert!(b.mxu_utilization < 1.0);
    }

    #[test]
    fn bigger_q_blocks_reduce_kv_traffic_when_streaming() {
        // 64k context: K/V (32 MiB) cannot stay VMEM-resident, so traffic
        // scales with the number of q-block passes.
        let mk = |bq| FlashConfig {
            block_q: bq,
            block_k: 128,
            head_dim: 128,
            q_len: 65536,
            kv_len: 65536,
            elem_bytes: 2.0,
        };
        let small = mk(64).analyze();
        let big = mk(256).analyze();
        assert!(big.hbm_bytes_per_row < small.hbm_bytes_per_row);
        assert!(big.arithmetic_intensity > small.arithmetic_intensity);
    }

    #[test]
    fn short_context_keeps_kv_resident() {
        let a = cfg(128, 128).analyze();
        // K+V at 4k/d128/bf16 = 4 MiB: resident, so HBM traffic is ~one
        // pass over Q,K,V,O.
        let one_pass = (4096.0 * 128.0 * 2.0) * 4.0;
        assert!(a.hbm_bytes_per_row < one_pass * 1.01);
    }

    #[test]
    fn best_blocks_is_mxu_aligned_and_fits() {
        let (bq, bk, a) = best_blocks(4096, 4096, 128);
        assert_eq!(bq % 128, 0);
        assert_eq!(bk % 64, 0);
        assert!(a.fits_vmem);
        assert!(a.roofline_efficiency > 0.5, "{}", a.roofline_efficiency);
    }

    #[test]
    fn long_context_stays_compute_bound() {
        let a = cfg(128, 128).analyze();
        // flash attention at 4k context should beat machine balance
        assert!(
            a.roofline_efficiency > 0.8,
            "AI {} roofline {}",
            a.arithmetic_intensity,
            a.roofline_efficiency
        );
    }
}
