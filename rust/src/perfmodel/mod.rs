//! Analytical hardware performance model — the simulated substitute for
//! the paper's physical testbeds (DESIGN.md §2).
//!
//! The paper evaluates on 256–512 H100s, TPU v5p-512/1024, 1024 Trainium2
//! (Table 3) and up to 32,768 TPU chips (Figure 4).  None of that hardware
//! exists here, so scale experiments run on this model: a roofline +
//! communication cost estimator over the *real parallelism plans* the
//! composer emits.  What the paper's numbers actually measure — remat
//! granularity, sharding strategy, compute/comm overlap, kernel fusion
//! quality — are exactly the inputs here, so orderings and ratios are
//! preserved even though absolute seconds are synthetic.
//!
//! Modules:
//! * [`chips`] — accelerator spec sheets (public figures, cited inline).
//! * [`model_shapes`] — FLOPs/bytes/param math for transformer shapes.
//! * [`comms`] — collective cost model over hierarchical interconnects.
//! * [`parallelism`] — strategy validation and per-axis communication.
//! * [`remat`] — rematerialization policy cost semantics.
//! * [`estimator`] — step-time / MFU / HBM estimates (Table 3, Figure 4).
//! * [`kernels`] — L1 kernel VMEM/MXU structural analysis (§Perf).

pub mod chips;
pub mod comms;
pub mod estimator;
pub mod kernels;
pub mod model_shapes;
pub mod parallelism;
pub mod remat;

pub use chips::ChipSpec;
pub use estimator::{estimate_step, Estimate, SystemProfile};
pub use model_shapes::TransformerShape;
pub use parallelism::Strategy;
