//! Step-time / MFU / memory estimator: the engine behind Table 3 and
//! Figure 4.
//!
//! The estimate combines:
//! * roofline compute time (chip peak × kernel efficiency × quantization);
//! * rematerialization recompute and residency ([`super::remat`]);
//! * per-axis collective costs over the hierarchical interconnect
//!   ([`super::comms`]), with a compute/comm overlap model;
//! * memory-bound elementwise traffic, scaled by the system's fusion
//!   quality (the paper's "RMSNorm and RoPE fused without hand-written
//!   kernels" point — §7.2);
//! * an HBM residency check that produces the paper's OOM rows.
//!
//! System-specific behavior enters only through [`SystemProfile`] — the
//! documented behavioral model of each baseline (see `baselines/`).

use anyhow::{bail, Result};

use super::chips::ChipSpec;
use super::comms::{hierarchical, Collective};
use super::model_shapes::TransformerShape;
use super::parallelism::Strategy;
use super::remat;

/// Behavioral profile of a training system (see `crate::baselines`).
#[derive(Clone, Debug)]
pub struct SystemProfile {
    pub name: &'static str,
    /// Multiplier on the GPU-family base kernel efficiency (1.0 = as good
    /// as the best hand-tuned CUDA stack).
    pub kernel_efficiency: f64,
    /// Multiplier on the TPU/Trainium base efficiency (XLA-first systems
    /// differ here: e.g. MaxText's remat/config defaults cost it a few
    /// points on TPU — §7.2's "likely due to choices on rematerialization").
    pub kernel_efficiency_tpu: f64,
    /// Fraction of collective time hidden behind compute.
    pub overlap_fraction: f64,
    /// 1.0 = memory-bound elementwise ops fully fused; >1 multiplies
    /// elementwise HBM traffic (unfused RMSNorm/RoPE etc.).
    pub fusion_overhead: f64,
    /// Remat policies this system can express (granularity, §7.2).
    pub allowed_remat: Vec<&'static str>,
    /// Whether activation/optimizer offload to host is supported.
    pub supports_offload: bool,
    /// Whether 8-bit quantized training is supported on this stack.
    pub supports_quant: bool,
    /// Extra transient bytes per parameter held across the compiled step
    /// (e.g. PyTorch XLA FSDP materializing full-size f32 gradients
    /// inside the XLA step — the mechanism behind the paper's 70B@v5p
    /// OOM row). 0 for well-behaved stacks.
    pub transient_bytes_per_param: f64,
}

impl SystemProfile {
    pub fn axlearn() -> Self {
        SystemProfile {
            name: "AXLearn",
            kernel_efficiency: 0.95, // XLA-on-GPU still slightly behind CUDA (§7.2)
            kernel_efficiency_tpu: 1.0, // first-class TPU tuning
            overlap_fraction: 0.85,
            fusion_overhead: 1.0,
            allowed_remat: vec!["none", "save_linear", "save_qkvo", "offload_dots", "full"],
            supports_offload: true,
            supports_quant: true,
            transient_bytes_per_param: 0.0,
        }
    }
}

/// Base achievable matmul efficiency per chip family (compiler/hw
/// maturity; the paper: "JAX/XLA on GPU is relatively nascent", Trainium2
/// "less robust early in their lifecycle").
pub fn base_efficiency(chip: &ChipSpec) -> f64 {
    match chip.name {
        "H100" => 0.62,
        "TPUv5p" => 0.72,
        "TPUv5e" => 0.62,
        "TPUv6e" => 0.68,
        "Trainium2" => 0.30,
        _ => 0.5,
    }
}

/// The estimate for one training step.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub step_time_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub exposed_comm_s: f64,
    pub hbm_traffic_s: f64,
    pub mfu: f64,
    pub tokens_per_s: f64,
    pub hbm_used_bytes: f64,
    pub hbm_capacity: f64,
    pub remat_policy: String,
}

/// Inputs for one estimate.
#[derive(Clone, Debug)]
pub struct StepSpec {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub global_batch: usize,
    pub seq_len: usize,
    /// "none" | "int8" | "fp8"
    pub quantization: String,
    /// Remat policy request; "auto" picks the best fitting allowed policy.
    pub remat_policy: String,
}

/// Estimate a training step; errors with an OOM message when the plan does
/// not fit in HBM (the AOT-compile check of §4.2 catches exactly this).
pub fn estimate_step(spec: &StepSpec, chip: &ChipSpec, profile: &SystemProfile) -> Result<Estimate> {
    let s = &spec.strategy;
    s.validate(spec.global_batch, spec.shape.num_layers as usize)?;
    let chips = s.total_chips();
    let shape = &spec.shape;
    let n_params = shape.params() as f64;

    // ---- memory budget --------------------------------------------------
    // expert ranks hold disjoint expert banks, so the expert axis shards
    // optimizer state like the other model axes (SageMaker-style uniform
    // grid; the mesh trainer partitions state the same way)
    let shard = (s.fsdp * s.tensor * s.pipeline * s.expert) as f64;
    // bf16 params + f32 master + adam m/v  (14 bytes/param), sharded
    let state_bytes = n_params * 14.0 / shard
        // system-specific unsharded transients (see SystemProfile)
        + n_params * profile.transient_bytes_per_param / (s.tensor * s.pipeline) as f64;
    // tokens per data-parallel shard (sequence parallelism splits tokens
    // when sequences are scarcer than shards)
    let tokens_per_replica = (spec.global_batch * spec.seq_len) / (s.data * s.fsdp);
    let layers_resident = shape.num_layers as f64 / s.pipeline as f64;
    // 1F1B pipelining keeps at most `pipeline` of the `microbatches`
    // in flight, shrinking resident activations proportionally.
    let pipeline_residency = if s.pipeline > 1 {
        (s.pipeline as f64 / s.microbatches as f64).min(1.0)
    } else {
        1.0
    };
    let act_full = tokens_per_replica as f64
        * shape.act_bytes_per_token_layer(spec.seq_len as u64)
        * layers_resident
        / s.tensor as f64
        * pipeline_residency;
    let overhead = 2e9; // compiler scratch, buffers, framework
    let hbm_budget = chip.hbm_bytes * 0.92;

    // resolve remat policy
    let allowed: Vec<&str> = profile
        .allowed_remat
        .iter()
        .copied()
        .filter(|p| *p != "offload_dots" || (profile.supports_offload && chip.host_bw > 0.0))
        .collect();
    let rcost = if spec.remat_policy == "auto" {
        match remat::best_fitting_policy(&allowed, act_full, state_bytes + overhead, hbm_budget) {
            Some(c) => c,
            None => bail!(
                "OOM: {} on {}: state {:.1} GB + min activations exceed {:.1} GB HBM (chips={chips})",
                shape.name,
                chip.name,
                state_bytes / 1e9,
                chip.hbm_bytes / 1e9
            ),
        }
    } else {
        if !allowed.contains(&spec.remat_policy.as_str()) {
            bail!(
                "{}: remat policy {:?} not expressible (allowed: {allowed:?})",
                profile.name,
                spec.remat_policy
            );
        }
        remat::cost(&spec.remat_policy)
    };
    let hbm_used = state_bytes + overhead + act_full * rcost.act_bytes_kept;
    if hbm_used > hbm_budget {
        bail!(
            "OOM: {} on {} with remat={}: {:.1} GB needed > {:.1} GB budget",
            shape.name,
            chip.name,
            rcost.policy,
            hbm_used / 1e9,
            hbm_budget / 1e9
        );
    }

    // ---- compute ---------------------------------------------------------
    let total_tokens = (spec.global_batch * spec.seq_len) as f64;
    let model_flops = total_tokens * shape.train_flops_per_token(spec.seq_len as u64);
    // recompute adds a fraction of the forward pass (fwd = 1/3 of train)
    let recompute_factor = 1.0 + rcost.recompute_frac / 3.0;
    let quant_speedup = match spec.quantization.as_str() {
        "int8" | "fp8" if profile.supports_quant => {
            // matmul share (~95%) runs at 8-bit peak
            let ratio = chip.peak_flops_8bit / chip.peak_flops_bf16;
            1.0 / (0.95 / ratio + 0.05)
        }
        _ => 1.0,
    };
    let sys_eff = if chip.name.starts_with("TPU") || chip.name == "Trainium2" {
        profile.kernel_efficiency_tpu
    } else {
        profile.kernel_efficiency
    };
    let eff = base_efficiency(chip) * sys_eff;
    let flops_per_chip = model_flops * recompute_factor / chips as f64;
    let compute_s = flops_per_chip / (chip.peak_flops_bf16 * eff * quant_speedup);

    // memory-bound elementwise traffic (norms, rope, residuals):
    let elementwise_bytes = tokens_per_replica as f64
        * (8.0 * shape.model_dim as f64 * 2.0)
        * layers_resident
        / s.tensor as f64
        * profile.fusion_overhead;
    let hbm_traffic_s = elementwise_bytes / chip.hbm_bw;
    // host offload DMA, overlapped at host_bw
    let offload_s = if rcost.offload_frac > 0.0 {
        (act_full * rcost.offload_frac * 2.0) / chip.host_bw.max(1.0) * 0.3 // mostly hidden
    } else {
        0.0
    };

    // ---- communication ----------------------------------------------------
    let ic = &chip.interconnect;
    let param_bytes_tp_shard = n_params * 2.0 / s.tensor as f64;
    let mut comm_s = 0.0;
    if s.fsdp > 1 {
        // ZeRO-3: all-gather params (fwd), all-gather (bwd), reduce-scatter grads
        comm_s += hierarchical(Collective::AllGather, param_bytes_tp_shard, s.fsdp, ic) * 2.0;
        comm_s += hierarchical(Collective::ReduceScatter, param_bytes_tp_shard, s.fsdp, ic);
    }
    if s.data > 1 {
        // grad all-reduce across pure-DP replicas (slow network when the
        // fast domain is consumed by fsdp/tp)
        let grad_bytes = n_params * 2.0 / (s.tensor * s.fsdp) as f64;
        let spans_domain = s.fsdp * s.tensor >= ic.domain_size;
        let t = if spans_domain {
            super::comms::inter_domain(Collective::AllReduce, grad_bytes, s.data, ic)
        } else {
            hierarchical(Collective::AllReduce, grad_bytes, s.data, ic)
        };
        comm_s += t;
    }
    if s.tensor > 1 {
        // Megatron-style: 4 all-reduces of activations per layer per step
        // (2 fwd + 2 bwd), tensor group lives in the fast domain
        let act_bytes = tokens_per_replica as f64 * shape.model_dim as f64 * 2.0;
        comm_s += 4.0
            * layers_resident
            * super::comms::intra_domain(Collective::AllReduce, act_bytes, s.tensor, ic);
    }
    if s.expert > 1 {
        // 2 all-to-alls per MoE layer fwd + 2 bwd — the shared formula
        // (`comms::expert_tok_bytes`/`expert_alltoall_cost`) that
        // `composer::build_schedule` prices into its AllToAll entries,
        // so the two cost models cannot drift apart
        let tok_bytes = super::comms::expert_tok_bytes(
            spec.global_batch,
            spec.seq_len,
            s.data * s.fsdp,
            shape.model_dim,
        );
        comm_s += super::comms::expert_alltoall_cost(tok_bytes, layers_resident, s.expert, ic);
    }
    if s.pipeline > 1 {
        let act_bytes =
            tokens_per_replica as f64 / s.microbatches as f64 * shape.model_dim as f64 * 2.0;
        comm_s += (s.pipeline - 1) as f64
            * s.microbatches as f64
            * (act_bytes / ic.intra_bw + ic.intra_latency)
            * 2.0; // fwd + bwd
    }

    let exposed = comm_s * (1.0 - profile.overlap_fraction);
    let bubble = 1.0 / (1.0 - s.strategy_bubble());
    // Straggler/jitter inflation: synchronous steps run at the speed of
    // the slowest participant; fleet-scale tail effects grow ~log with
    // chip count (MegaScale [20] documents this at 10k+ GPUs).  This is
    // the dominant Figure-4 MFU-decline mechanism once collectives are
    // overlapped.
    let straggler = 1.0 + 0.04 * ((chips as f64 / 256.0).log2()).max(0.0);
    let step_time = (compute_s + hbm_traffic_s + exposed + offload_s) * bubble * straggler;

    let mfu = model_flops / (step_time * chips as f64 * chip.peak_flops_bf16);
    Ok(Estimate {
        step_time_s: step_time,
        compute_s,
        comm_s,
        exposed_comm_s: exposed,
        hbm_traffic_s,
        mfu,
        tokens_per_s: total_tokens / step_time,
        hbm_used_bytes: hbm_used,
        hbm_capacity: chip.hbm_bytes,
        remat_policy: rcost.policy.to_string(),
    })
}

trait StrategyExt {
    fn strategy_bubble(&self) -> f64;
}

impl StrategyExt for Strategy {
    fn strategy_bubble(&self) -> f64 {
        self.pipeline_bubble()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::chips;

    fn spec_7b(chips_n: usize, fsdp: usize, tensor: usize) -> StepSpec {
        StepSpec {
            shape: TransformerShape::llama2_7b(),
            strategy: Strategy {
                data: chips_n / (fsdp * tensor),
                fsdp,
                tensor,
                ..Default::default()
            },
            global_batch: 1024,
            seq_len: 4096,
            quantization: "none".into(),
            remat_policy: "auto".into(),
        }
    }

    #[test]
    fn mfu_is_physical() {
        let e = estimate_step(&spec_7b(256, 256, 1), &chips::h100(), &SystemProfile::axlearn()).unwrap();
        assert!(e.mfu > 0.2 && e.mfu < 0.75, "mfu {}", e.mfu);
        assert!(e.step_time_s > 0.0);
        assert!(e.hbm_used_bytes < e.hbm_capacity);
    }

    #[test]
    fn more_chips_is_faster_but_lower_mfu_across_domains() {
        let prof = SystemProfile::axlearn();
        let small = estimate_step(&spec_7b(256, 256, 1), &chips::h100(), &prof).unwrap();
        let big = estimate_step(&spec_7b(1024, 256, 1), &chips::h100(), &prof).unwrap();
        assert!(big.step_time_s < small.step_time_s);
        // More chips at fixed global batch can shift the remat choice
        // (fewer tokens/replica => less recompute), so MFU may move either
        // way — but never by much.
        assert!(big.mfu <= small.mfu * 1.25 && big.mfu >= small.mfu * 0.5);
    }

    #[test]
    fn oom_when_model_too_big_for_strategy() {
        // 70B, tiny fsdp degree, no remat allowed: state alone > HBM
        let spec = StepSpec {
            shape: TransformerShape::llama2_70b(),
            strategy: Strategy::fsdp_only(8),
            global_batch: 1024,
            seq_len: 4096,
            quantization: "none".into(),
            remat_policy: "auto".into(),
        };
        let err = estimate_step(&spec, &chips::h100(), &SystemProfile::axlearn()).unwrap_err();
        assert!(err.to_string().contains("OOM"), "{err}");
    }

    #[test]
    fn quantization_speeds_up() {
        let mut spec = spec_7b(256, 256, 1);
        let prof = SystemProfile::axlearn();
        let base = estimate_step(&spec, &chips::h100(), &prof).unwrap();
        spec.quantization = "fp8".into();
        let quant = estimate_step(&spec, &chips::h100(), &prof).unwrap();
        assert!(quant.step_time_s < base.step_time_s * 0.75);
    }

    #[test]
    fn coarse_remat_system_is_slower() {
        // Same hardware, same strategy; block-granularity remat forces the
        // full-recompute policy under memory pressure -> slower step (the
        // §7.2 FSDP story).
        let fine = SystemProfile::axlearn();
        let coarse = SystemProfile {
            name: "BlockRemat",
            allowed_remat: vec!["none", "full"],
            ..SystemProfile::axlearn()
        };
        let spec = StepSpec {
            shape: TransformerShape::llama2_70b(),
            strategy: Strategy::fsdp_only(512),
            global_batch: 1024,
            seq_len: 4096,
            quantization: "none".into(),
            remat_policy: "auto".into(),
        };
        let e_fine = estimate_step(&spec, &chips::h100(), &fine).unwrap();
        let e_coarse = estimate_step(&spec, &chips::h100(), &coarse).unwrap();
        assert!(
            e_coarse.step_time_s > e_fine.step_time_s,
            "coarse {} fine {}",
            e_coarse.step_time_s,
            e_fine.step_time_s
        );
        assert_ne!(e_fine.remat_policy, "full");
        assert_eq!(e_coarse.remat_policy, "full");
    }

    #[test]
    fn tensor_parallel_adds_comm() {
        let prof = SystemProfile::axlearn();
        let fsdp_only = estimate_step(&spec_7b(256, 256, 1), &chips::h100(), &prof).unwrap();
        let with_tp = estimate_step(&spec_7b(256, 32, 8), &chips::h100(), &prof).unwrap();
        assert!(with_tp.comm_s > fsdp_only.comm_s * 0.5);
    }

    #[test]
    fn weak_scaling_mfu_declines_gently() {
        // Figure-4 mechanism: fixed per-device batch, growing chips.
        let prof = SystemProfile::axlearn();
        let shape = TransformerShape::model_a_70b();
        let mut mfus = Vec::new();
        for chips_n in [256usize, 1024, 4096] {
            let spec = StepSpec {
                shape: shape.clone(),
                strategy: Strategy {
                    data: chips_n / 256,
                    fsdp: 256,
                    ..Default::default()
                },
                global_batch: chips_n, // fixed per-device batch of 1 seq
                seq_len: 4096,
                quantization: "none".into(),
                remat_policy: "auto".into(),
            };
            mfus.push(estimate_step(&spec, &chips::tpu_v5p(), &prof).unwrap().mfu);
        }
        assert!(mfus[0] > mfus[2], "{mfus:?}");
        assert!(mfus[2] > mfus[0] * 0.7, "near-linear scaling: {mfus:?}");
    }
}
