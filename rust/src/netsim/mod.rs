//! Flow-level network simulation under the collective schedules — the
//! ground truth the analytic [`crate::perfmodel::comms`] model is
//! validated against (`docs/netsim.md`).
//!
//! The analytic model prices every mesh with the same payload
//! identically, regardless of topology or contention.  This module
//! executes the *same* [`crate::composer::CollectiveSchedule`] entries
//! over an explicit link graph instead:
//!
//! * [`sim`] — the event-driven fluid engine: a deterministic
//!   [`sim::EventQueue`], a virtual clock, and max-min fair-shared
//!   link bandwidth between concurrent flows.
//! * [`net`] — links and the progressive-filling fair-share
//!   allocation.
//! * [`topo`] — topology builders ([`Topology::single_domain`],
//!   [`Topology::two_tier`], [`Topology::dumbbell`]) sized from a
//!   [`crate::perfmodel::chips::Interconnect`], plus seeded per-host
//!   straggler jitter.
//! * [`algos`] — ring/tree/hierarchical lowering of each collective to
//!   per-link flows.
//!
//! The schedule-level entry point is
//! [`CollectiveSchedule::simulate`](crate::composer::CollectiveSchedule):
//! each entry's `count` subgroup instances are laid out block-wise over
//! the hosts (instance `k` on hosts `k·group .. (k+1)·group`), lowered
//! together into one flow set, and run to completion; the entry's
//! simulated seconds are the makespan times its `rounds` repetition
//! factor.  Entries are independent simulations, so
//! [`NetSimOptions::sim_threads`] fans them across worker threads with
//! bit-identical results at any thread count (the determinism suite
//! pins this).
//!
//! Consumers: `composer::mesh_sweep` adds topology-aware columns to
//! `bench_mesh.json` (gated by `bench_check` against
//! `benches/baseline.json`), `distributed::sim_bench` reports a
//! simulated comm time next to its work counters, and
//! `rust/tests/netsim_validation.rs` holds the tolerance contract
//! against the analytic model.

pub mod algos;
pub mod net;
pub mod sim;
pub mod topo;

pub use algos::{lower_collective, simulate_collective, AlgoChoice};
pub use net::Link;
pub use sim::{simulate_flows, EventQueue, FlowOutcome, FlowSpec, Timeline};
pub use topo::{Topology, TopologyKind};

use anyhow::Result;

use crate::composer::schedule::{CollectiveSchedule, ScheduleEntry};

/// How to run a schedule through the simulator.
#[derive(Clone, Copy, Debug)]
pub struct NetSimOptions {
    /// Lowering family per entry ([`AlgoChoice::Auto`] picks
    /// hierarchical exactly when the subgroup spans pods).
    pub algo: AlgoChoice,
    /// Worker threads to fan independent entries across (1 = inline).
    /// Results are bit-identical at any setting.
    pub sim_threads: usize,
}

impl Default for NetSimOptions {
    fn default() -> Self {
        NetSimOptions { algo: AlgoChoice::Auto, sim_threads: 1 }
    }
}

/// One schedule entry's simulated outcome next to its analytic cost.
#[derive(Clone, Debug)]
pub struct EntrySim {
    /// The entry's `tensor` label (join key for reporting).
    pub tensor: String,
    /// The entry's mesh axis.
    pub axis: String,
    /// The analytic cost the schedule carries (`ScheduleEntry::cost_s`).
    pub analytic_s: f64,
    /// Simulated seconds: flow-set makespan × the entry's `rounds`.
    pub sim_s: f64,
    /// Whether the entry hides behind compute (copied from the entry).
    pub overlappable: bool,
    /// Flows in the lowered set (all `count` instances).
    pub flows: usize,
    /// Events the fluid engine processed.
    pub events: usize,
}

/// A schedule run through the simulator: per-entry outcomes plus the
/// same exposed/overlappable totals the analytic schedule offers, so
/// the two cost models compose step time identically.
#[derive(Clone, Debug)]
pub struct ScheduleSim {
    pub entries: Vec<EntrySim>,
}

impl ScheduleSim {
    /// Total simulated communication time (sum over entries).
    pub fn total_sim_s(&self) -> f64 {
        self.entries.iter().map(|e| e.sim_s).sum()
    }

    /// Simulated communication on the critical path.
    pub fn exposed_sim_s(&self) -> f64 {
        self.entries.iter().filter(|e| !e.overlappable).map(|e| e.sim_s).sum()
    }

    /// Simulated communication that can hide behind compute.
    pub fn overlappable_sim_s(&self) -> f64 {
        self.total_sim_s() - self.exposed_sim_s()
    }

    /// Step-time composition mirroring
    /// [`CollectiveSchedule::step_time_s`], with simulated times.
    pub fn step_time_s(&self, compute_s: f64) -> f64 {
        compute_s + self.exposed_sim_s() + (self.overlappable_sim_s() - compute_s).max(0.0)
    }
}

/// Simulate one entry: all `count` instances lowered into one flow set
/// (instance `k` on the host block `k·group .. (k+1)·group`), run to
/// completion, scaled by the entry's repetition factor.
fn simulate_entry(entry: &ScheduleEntry, topo: &Topology, algo: AlgoChoice) -> Result<EntrySim> {
    let done = |sim_s: f64, flows: usize, events: usize| EntrySim {
        tensor: entry.tensor.clone(),
        axis: entry.axis.clone(),
        analytic_s: entry.cost_s,
        sim_s,
        overlappable: entry.overlappable,
        flows,
        events,
    };
    if entry.group < 2 {
        return Ok(done(0.0, 0, 0));
    }
    anyhow::ensure!(
        entry.group * entry.count <= topo.hosts(),
        "entry {:?}/{}: {}x{} subgroup instances exceed the {}-host topology",
        entry.collective,
        entry.tensor,
        entry.group,
        entry.count,
        topo.hosts()
    );
    let mut flows = Vec::new();
    for k in 0..entry.count.max(1) {
        let ranks: Vec<usize> = (k * entry.group..(k + 1) * entry.group).collect();
        algos::lower_collective_into(
            &mut flows,
            topo,
            algo,
            entry.collective,
            &ranks,
            entry.bytes,
        )?;
    }
    let tl = simulate_flows(topo, &flows)?;
    Ok(done(tl.makespan_s * entry.rounds.max(1) as f64, flows.len(), tl.events))
}

impl CollectiveSchedule {
    /// Execute every entry over `topo` with the given lowering and
    /// return simulated per-entry times (see [`ScheduleSim`]).
    pub fn simulate(&self, topo: &Topology, algo: AlgoChoice) -> Result<ScheduleSim> {
        self.simulate_with(topo, &NetSimOptions { algo, sim_threads: 1 })
    }

    /// [`CollectiveSchedule::simulate`] with explicit options.  Entries
    /// are independent simulations; `sim_threads > 1` fans them across
    /// scoped worker threads and merges in entry order, so the result
    /// is bit-identical at any thread count.
    pub fn simulate_with(&self, topo: &Topology, opts: &NetSimOptions) -> Result<ScheduleSim> {
        let threads = opts.sim_threads.max(1).min(self.entries.len().max(1));
        let mut slots: Vec<Option<Result<EntrySim>>> =
            (0..self.entries.len()).map(|_| None).collect();
        if threads <= 1 {
            for (i, e) in self.entries.iter().enumerate() {
                slots[i] = Some(simulate_entry(e, topo, opts.algo));
            }
        } else {
            let entries = &self.entries;
            let algo = opts.algo;
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for t in 0..threads {
                    handles.push(scope.spawn(move || {
                        entries
                            .iter()
                            .enumerate()
                            .skip(t)
                            .step_by(threads)
                            .map(|(i, e)| (i, simulate_entry(e, topo, algo)))
                            .collect::<Vec<_>>()
                    }));
                }
                for h in handles {
                    for (i, r) in h.join().expect("netsim worker panicked") {
                        slots[i] = Some(r);
                    }
                }
            });
        }
        let mut entries = Vec::with_capacity(slots.len());
        for s in slots {
            entries.push(s.expect("every entry simulated")?);
        }
        Ok(ScheduleSim { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::composer::schedule::build_schedule;
    use crate::perfmodel::chips;
    use crate::perfmodel::Strategy;
    use crate::perfmodel::TransformerShape;

    fn sched() -> CollectiveSchedule {
        let strat = Strategy { data: 4, fsdp: 8, tensor: 2, ..Strategy::default() };
        build_schedule(
            &strat,
            &TransformerShape::llama2_7b(),
            &["fsdp".to_string(), "model".to_string()],
            256,
            2048,
            &chips::h100().interconnect,
        )
    }

    #[test]
    fn schedule_simulation_produces_positive_times() {
        let topo = Topology::two_tier(64, &chips::h100().interconnect);
        let sim = sched().simulate(&topo, AlgoChoice::Auto).unwrap();
        assert_eq!(sim.entries.len(), sched().entries.len());
        for e in &sim.entries {
            assert!(e.sim_s > 0.0 && e.flows > 0, "{e:?}");
        }
        assert!(sim.total_sim_s() >= sim.exposed_sim_s());
        assert!(sim.step_time_s(0.0) >= sim.total_sim_s() - 1e-12);
    }

    #[test]
    fn thread_fanout_is_bit_identical() {
        let topo = Topology::two_tier(64, &chips::h100().interconnect);
        let s = sched();
        let base = s.simulate_with(&topo, &NetSimOptions { algo: AlgoChoice::Auto, sim_threads: 1 })
            .unwrap();
        for threads in [2, 3, 8] {
            let t = s
                .simulate_with(&topo, &NetSimOptions { algo: AlgoChoice::Auto, sim_threads: threads })
                .unwrap();
            for (a, b) in base.entries.iter().zip(&t.entries) {
                assert_eq!(a.sim_s.to_bits(), b.sim_s.to_bits(), "threads={threads}");
                assert_eq!(a.events, b.events, "threads={threads}");
            }
        }
    }

    #[test]
    fn oversized_subgroups_are_rejected() {
        let topo = Topology::single_domain(8, &chips::h100().interconnect);
        let err = sched().simulate(&topo, AlgoChoice::Ring);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("exceed"));
    }

    #[test]
    fn jittered_hosts_slow_the_simulation_deterministically() {
        let ic = chips::h100().interconnect;
        let clean = Topology::single_domain(64, &ic);
        let jittered = Topology::single_domain(64, &ic).with_host_jitter(7, 0.3);
        let s = sched();
        let a = s.simulate(&clean, AlgoChoice::Ring).unwrap();
        let b = s.simulate(&jittered, AlgoChoice::Ring).unwrap();
        let c = s.simulate(&jittered, AlgoChoice::Ring).unwrap();
        assert!(b.total_sim_s() > a.total_sim_s(), "stragglers must cost time");
        assert_eq!(b.total_sim_s().to_bits(), c.total_sim_s().to_bits(), "replayable");
    }
}
