//! Links and fair-shared bandwidth: the capacity model under the flow
//! simulator.
//!
//! A [`Link`] is a directed capacity (`bw` bytes/s) with a propagation
//! latency; a flow occupies an ordered list of link indices (its path).
//! When several flows share a link, the simulator splits the capacity
//! **max-min fairly** ([`fair_share_rates`]): repeatedly find the most
//! contended link, freeze every flow crossing it at that link's equal
//! share, subtract, and continue — the classic progressive-filling
//! construction.  The result is the unique max-min allocation, and the
//! implementation is deterministic: links are scanned in index order
//! and *every* link whose contention ratio is bit-equal to the minimum
//! freezes in the same pass, so symmetric topologies (every ring round
//! of a collective) resolve in one pass with bit-identical rates.

/// One directed link: finite bandwidth, fixed propagation latency.
#[derive(Clone, Debug)]
pub struct Link {
    /// Capacity in bytes/second.  Must be positive.
    pub bw: f64,
    /// Propagation latency in seconds (paid once per path by flows that
    /// model a cut-through start; see `sim::FlowSpec::pays_latency`).
    pub latency: f64,
    /// Human-readable name for diagnostics (`"up:3"`, `"trunk:0>1"`).
    pub label: String,
}

impl Link {
    pub fn new(bw: f64, latency: f64, label: impl Into<String>) -> Self {
        let link = Link { bw, latency, label: label.into() };
        assert!(link.bw > 0.0, "link {} needs positive bandwidth", link.label);
        assert!(link.latency >= 0.0, "link {} needs nonnegative latency", link.label);
        link
    }
}

/// Max-min fair rates for a set of concurrent flows.
///
/// `paths[k]` is flow `k`'s ordered link-index list (must be nonempty;
/// a flow crossing no link has no capacity constraint and does not
/// belong here).  Returns one rate per flow, aligned with `paths`.
pub fn fair_share_rates(links: &[Link], paths: &[&[usize]]) -> Vec<f64> {
    let mut rates = vec![0.0f64; paths.len()];
    if paths.is_empty() {
        return rates;
    }
    let mut residual: Vec<f64> = links.iter().map(|l| l.bw).collect();
    let mut alive: Vec<usize> = vec![0; links.len()];
    for path in paths {
        assert!(!path.is_empty(), "fair_share_rates: flow with an empty path");
        for &l in *path {
            alive[l] += 1;
        }
    }
    let mut frozen = vec![false; paths.len()];
    let mut remaining = paths.len();
    while remaining > 0 {
        // the most contended link level: min over live links of
        // residual capacity per crossing flow
        let mut level = f64::INFINITY;
        for (l, &n) in alive.iter().enumerate() {
            if n > 0 {
                let r = residual[l] / n as f64;
                if r < level {
                    level = r;
                }
            }
        }
        assert!(
            level.is_finite(),
            "fair_share_rates: {remaining} flows left but no live link"
        );
        // freeze every unfrozen flow crossing a link at exactly this
        // level — bit-equality keeps symmetric cases one-pass and
        // deterministic
        let bottleneck: Vec<bool> = alive
            .iter()
            .enumerate()
            .map(|(l, &n)| n > 0 && residual[l] / n as f64 == level)
            .collect();
        let mut froze_any = false;
        for (k, path) in paths.iter().enumerate() {
            if frozen[k] || !path.iter().any(|&l| bottleneck[l]) {
                continue;
            }
            frozen[k] = true;
            froze_any = true;
            remaining -= 1;
            rates[k] = level;
            for &l in *path {
                residual[l] = (residual[l] - level).max(0.0);
                alive[l] -= 1;
            }
        }
        assert!(froze_any, "fair_share_rates: progressive filling stalled");
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::*;

    fn links(bws: &[f64]) -> Vec<Link> {
        bws.iter()
            .enumerate()
            .map(|(i, &bw)| Link::new(bw, 1e-6, format!("l{i}")))
            .collect()
    }

    #[test]
    fn lone_flow_gets_the_full_link() {
        let ls = links(&[10.0]);
        let rates = fair_share_rates(&ls, &[&[0]]);
        assert_eq!(rates, vec![10.0]);
    }

    #[test]
    fn equal_flows_split_evenly() {
        let ls = links(&[12.0]);
        let rates = fair_share_rates(&ls, &[&[0], &[0], &[0]]);
        assert_eq!(rates, vec![4.0, 4.0, 4.0]);
    }

    #[test]
    fn textbook_max_min() {
        // f0 on l0 (bw 10), f1 on l0+l1, f2 on l1 (bw 6): l1 is the
        // bottleneck at 3 for f1/f2, leaving f0 the rest of l0
        let ls = links(&[10.0, 6.0]);
        let rates = fair_share_rates(&ls, &[&[0], &[0, 1], &[1]]);
        assert_eq!(rates[1], 3.0);
        assert_eq!(rates[2], 3.0);
        assert_eq!(rates[0], 7.0);
    }

    #[test]
    fn rates_never_exceed_any_crossed_link() {
        let ls = links(&[5.0, 2.0, 9.0]);
        let paths: Vec<&[usize]> = vec![&[0, 1], &[1, 2], &[0], &[2]];
        let rates = fair_share_rates(&ls, &paths);
        for l in 0..ls.len() {
            let load: f64 = paths
                .iter()
                .zip(&rates)
                .filter(|(p, _)| p.contains(&l))
                .map(|(_, &r)| r)
                .sum();
            assert!(load <= ls[l].bw + 1e-12, "link {l} overloaded: {load}");
        }
    }

    #[test]
    fn allocation_is_deterministic() {
        let ls = links(&[7.0, 3.0, 5.0, 5.0]);
        let paths: Vec<&[usize]> = vec![&[0, 1], &[1, 2], &[2, 3], &[3, 0], &[0], &[2]];
        let a = fair_share_rates(&ls, &paths);
        let b = fair_share_rates(&ls, &paths);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
