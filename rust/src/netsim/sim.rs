//! The event-driven fluid-flow engine: a virtual clock, a deterministic
//! event queue, and max-min rate sharing between whatever flows are
//! active at each instant.
//!
//! The model is flow-level ("fluid"), not packet-level: a flow is a
//! byte count draining along a fixed link path at whatever rate the
//! max-min allocation ([`super::net::fair_share_rates`]) gives it.  The
//! clock jumps between *events* — a dependency-released flow becoming
//! active, or an active flow draining to zero — and rates are
//! recomputed only at events.  Two properties the test suite pins:
//!
//! * **Determinism.**  Events at the same virtual time pop in insertion
//!   order ([`EventQueue`] breaks ties by sequence number), link scans
//!   are index-ordered, and flows that finish at bit-equal times
//!   complete in the same batch — so a timeline is a pure function of
//!   (topology, flow set), bit-identical across reruns and thread
//!   counts.
//! * **Conservation.**  Every byte a flow carries is accounted to every
//!   link on its path ([`Timeline::link_bytes`]); the property suite
//!   checks the ledger against the flow set exactly.
//!
//! Latency is start-up, not per-round: a flow with
//! [`FlowSpec::pays_latency`] waits its path's propagation latency
//! between becoming ready and becoming active.  Collective lowerings
//! (`super::algos`) set it on first-round flows only, modeling
//! cut-through pipelining — a ring pays its wire latency once, not once
//! per chunk, which is what keeps long rings within tolerance of the
//! analytic `(n-1)/n · bytes / bw + latency` costs.

use anyhow::Result;

use super::topo::Topology;

/// A deterministic min-heap of timed events: pops are nondecreasing in
/// time, and ties pop in push order.
#[derive(Debug)]
pub struct EventQueue<T> {
    // (time bits, sequence, payload); f64::to_bits preserves order for
    // the nonnegative finite times the simulator produces, and the
    // sequence number makes ties deterministic
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, u64, EventSlot<T>)>>,
    seq: u64,
}

/// Payload wrapper that never participates in heap ordering (the
/// `(time, seq)` prefix is already unique).
#[derive(Debug)]
struct EventSlot<T>(T);

impl<T> PartialEq for EventSlot<T> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<T> Eq for EventSlot<T> {}
impl<T> PartialOrd for EventSlot<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for EventSlot<T> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: std::collections::BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at virtual time `time` (finite, >= 0).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(
            time.is_finite() && time >= 0.0,
            "EventQueue::push: time must be finite and nonnegative, got {time}"
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(std::cmp::Reverse((time.to_bits(), seq, EventSlot(payload))));
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|std::cmp::Reverse((t, _, _))| f64::from_bits(*t))
    }

    /// Pop the earliest event (ties in push order).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap
            .pop()
            .map(|std::cmp::Reverse((t, _, EventSlot(p)))| (f64::from_bits(t), p))
    }
}

/// One flow: `bytes` from `src` to `dst`, eligible to start once every
/// flow in `deps` has finished.
#[derive(Clone, Debug)]
pub struct FlowSpec {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    /// Indices (into the same flow slice) that must finish first.
    pub deps: Vec<usize>,
    /// Pay the path's propagation latency between readiness and
    /// activation (set on round-0/root flows of a collective; follow-on
    /// rounds are cut-through pipelined and start immediately).
    pub pays_latency: bool,
}

/// Per-flow result: when it started draining and when it finished.
#[derive(Clone, Copy, Debug)]
pub struct FlowOutcome {
    pub start_s: f64,
    pub finish_s: f64,
}

/// A completed simulation: per-flow outcomes, the makespan, and the
/// per-link byte ledger.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub flows: Vec<FlowOutcome>,
    /// Finish time of the last flow (0 for an empty flow set).
    pub makespan_s: f64,
    /// Bytes carried by each link, indexed like
    /// [`Topology::links`] — conserved against the flow set.
    pub link_bytes: Vec<f64>,
    /// Events processed (activations + completions), for
    /// instrumentation.
    pub events: usize,
}

/// Run a flow set to completion over `topo`.  Errors on malformed
/// specs (bad endpoints, negative bytes, dangling or cyclic
/// dependencies).
pub fn simulate_flows(topo: &Topology, specs: &[FlowSpec]) -> Result<Timeline> {
    let n = specs.len();
    let links = topo.links();
    let mut paths = Vec::with_capacity(n);
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut pending: Vec<usize> = vec![0; n];
    for (i, f) in specs.iter().enumerate() {
        anyhow::ensure!(f.bytes >= 0.0 && f.bytes.is_finite(), "flow {i}: bad byte count");
        paths.push(topo.path(f.src, f.dst));
        for &d in &f.deps {
            anyhow::ensure!(d < n, "flow {i}: dependency {d} out of range");
            anyhow::ensure!(d != i, "flow {i}: depends on itself");
            children[d].push(i);
            pending[i] += 1;
        }
    }

    let mut outcomes = vec![FlowOutcome { start_s: f64::NAN, finish_s: f64::NAN }; n];
    let mut remaining: Vec<f64> = specs.iter().map(|f| f.bytes).collect();
    let mut link_bytes = vec![0.0f64; links.len()];
    let mut queue: EventQueue<usize> = EventQueue::new();
    let mut active: Vec<usize> = Vec::new();
    let mut finished = vec![false; n];
    let mut events = 0usize;
    let mut now = 0.0f64;

    let activation_time = |now: f64, i: usize, paths: &[Vec<usize>]| {
        if specs[i].pays_latency {
            now + topo.path_latency(&paths[i])
        } else {
            now
        }
    };
    for i in 0..n {
        if pending[i] == 0 {
            queue.push(activation_time(0.0, i, &paths), i);
        }
    }

    loop {
        // next completion among active flows under current fair shares
        let active_paths: Vec<&[usize]> = active.iter().map(|&i| paths[i].as_slice()).collect();
        let rates = super::net::fair_share_rates(links, &active_paths);
        let mut next_done = f64::INFINITY;
        let done_at: Vec<f64> = active
            .iter()
            .zip(&rates)
            .map(|(&i, &r)| {
                let t = if r > 0.0 { now + remaining[i] / r } else { f64::INFINITY };
                if t < next_done {
                    next_done = t;
                }
                t
            })
            .collect();
        let next_act = queue.peek_time().unwrap_or(f64::INFINITY);
        let t = next_done.min(next_act);
        if !t.is_finite() {
            break;
        }

        // drain active flows to t, crediting every crossed link;
        // bit-equal finishers complete together in this batch
        let mut completed = Vec::new();
        for (k, &i) in active.iter().enumerate() {
            let delta = if done_at[k] <= t {
                completed.push(i);
                remaining[i]
            } else {
                rates[k] * (t - now)
            };
            remaining[i] -= delta;
            for &l in &paths[i] {
                link_bytes[l] += delta;
            }
        }
        now = t;

        let mut newly_done = completed;
        while let Some(i) = newly_done.pop() {
            finished[i] = true;
            outcomes[i].finish_s = now;
            events += 1;
            for &c in &children[i] {
                pending[c] -= 1;
                if pending[c] == 0 {
                    queue.push(activation_time(now, c, &paths), c);
                }
            }
        }
        active.retain(|&i| !finished[i]);

        // activations due now (pushes from the completions above with
        // zero latency land at exactly `now` and start this instant)
        while queue.peek_time().is_some_and(|ta| ta <= now) {
            let (_, i) = queue.pop().expect("peeked");
            events += 1;
            outcomes[i].start_s = now;
            if remaining[i] == 0.0 {
                // zero-byte flow: completes instantly, may release more
                finished[i] = true;
                outcomes[i].finish_s = now;
                for &c in &children[i] {
                    pending[c] -= 1;
                    if pending[c] == 0 {
                        queue.push(activation_time(now, c, &paths), c);
                    }
                }
            } else {
                active.push(i);
            }
        }
        active.sort_unstable();
    }

    anyhow::ensure!(
        finished.iter().all(|&f| f),
        "simulate_flows: {} flows never ran (dependency cycle)",
        finished.iter().filter(|&&f| !f).count()
    );
    let makespan_s = outcomes.iter().map(|o| o.finish_s).fold(0.0f64, f64::max);
    Ok(Timeline { flows: outcomes, makespan_s, link_bytes, events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::chips;

    fn topo(hosts: usize) -> Topology {
        Topology::single_domain(hosts, &chips::h100().interconnect)
    }

    fn flow(src: usize, dst: usize, bytes: f64, deps: &[usize]) -> FlowSpec {
        FlowSpec { src, dst, bytes, deps: deps.to_vec(), pays_latency: false }
    }

    #[test]
    fn event_queue_pops_nondecreasing_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, "late");
        q.push(1.0, "a");
        q.push(1.0, "b");
        q.push(0.5, "first");
        assert_eq!(q.peek_time(), Some(0.5));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["first", "a", "b", "late"]);
    }

    #[test]
    fn lone_flow_takes_bytes_over_bandwidth() {
        let t = topo(2);
        let bw = t.links()[0].bw;
        let tl = simulate_flows(&t, &[flow(0, 1, 9e9, &[])]).unwrap();
        assert!((tl.makespan_s - 9e9 / bw).abs() < 1e-12);
        assert_eq!(tl.link_bytes.iter().filter(|&&b| b > 0.0).count(), 2);
    }

    #[test]
    fn latency_is_paid_once_at_activation() {
        let t = topo(2);
        let bw = t.links()[0].bw;
        let lat = chips::h100().interconnect.intra_latency;
        let mut f = flow(0, 1, 9e9, &[]);
        f.pays_latency = true;
        let tl = simulate_flows(&t, &[f]).unwrap();
        assert!((tl.flows[0].start_s - lat).abs() < 1e-15);
        assert!((tl.makespan_s - (lat + 9e9 / bw)).abs() < 1e-12);
    }

    #[test]
    fn dependencies_serialize_flows() {
        let t = topo(3);
        let bw = t.links()[0].bw;
        let tl =
            simulate_flows(&t, &[flow(0, 1, 4e9, &[]), flow(1, 2, 4e9, &[0])]).unwrap();
        assert!((tl.flows[1].start_s - tl.flows[0].finish_s).abs() < 1e-15);
        assert!((tl.makespan_s - 2.0 * 4e9 / bw).abs() < 1e-12);
    }

    #[test]
    fn sharing_halves_the_rate() {
        // two flows into the same destination host: its down link is
        // the bottleneck, so each drains at bw/2
        let t = topo(3);
        let bw = t.links()[0].bw;
        let tl = simulate_flows(&t, &[flow(0, 2, 6e9, &[]), flow(1, 2, 6e9, &[])]).unwrap();
        assert!((tl.makespan_s - 12e9 / bw).abs() < 1e-12, "{}", tl.makespan_s);
    }

    #[test]
    fn zero_byte_flows_release_dependents() {
        let t = topo(3);
        let tl =
            simulate_flows(&t, &[flow(0, 1, 0.0, &[]), flow(1, 2, 1e9, &[0])]).unwrap();
        assert_eq!(tl.flows[0].finish_s, 0.0);
        assert!(tl.makespan_s > 0.0);
    }

    #[test]
    fn dependency_cycles_are_an_error() {
        let t = topo(2);
        let err = simulate_flows(&t, &[flow(0, 1, 1.0, &[1]), flow(1, 0, 1.0, &[0])]);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("cycle"));
    }

    #[test]
    fn link_ledger_conserves_bytes() {
        let t = topo(4);
        let specs = vec![
            flow(0, 1, 3e9, &[]),
            flow(1, 2, 5e9, &[]),
            flow(2, 3, 7e9, &[1]),
            flow(3, 0, 2e9, &[0, 2]),
        ];
        let tl = simulate_flows(&t, &specs).unwrap();
        let expected: f64 = specs.iter().map(|f| 2.0 * f.bytes).sum(); // 2 links/path
        let total: f64 = tl.link_bytes.iter().sum();
        assert!((total - expected).abs() < 1.0, "{total} vs {expected}");
    }
}
