//! Topology builders: explicit link graphs sized from a
//! [`crate::perfmodel::chips::Interconnect`].
//!
//! Three shapes, one per modeling need (`docs/netsim.md`):
//!
//! * [`Topology::single_domain`] — every host hangs off one
//!   non-blocking switch at `intra_bw`.  The contention-free reference:
//!   on it the simulator must reproduce the analytic
//!   [`crate::perfmodel::comms`] costs (the tolerance test's contract).
//! * [`Topology::two_tier`] — pods of `domain_size` hosts, each pod
//!   uplinked to a spine by a trunk of `pod_size × inter_bw` (every
//!   chip contributes its slow-network injection bandwidth).  The
//!   realistic shape behind the sweep's topology-aware columns.
//! * [`Topology::dumbbell`] — two halves joined by a deliberately
//!   oversubscribed trunk.  Exists to *create* contention the analytic
//!   model cannot see; the validation suite asserts simulated time
//!   strictly exceeds the analytic bound here.
//!
//! All links are directed; a host has one `up` link into its switch and
//! one `down` link out of it, so a host-to-host path is `up → (trunks)
//! → down` and intra-pod one-hop latency totals `intra_latency`
//! (`intra_latency/2` per access link).  Cross-pod paths total
//! `inter_latency`.  [`Topology::with_host_jitter`] derates per-host
//! access bandwidth from a seeded [`crate::util::rng::Rng`] — the
//! deterministic, replayable straggler model.

use crate::perfmodel::chips::Interconnect;
use crate::util::rng::Rng;

use super::net::Link;

/// Which builder produced a [`Topology`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    SingleDomain,
    TwoTier,
    Dumbbell,
}

/// An explicit directed link graph with precomputed host access links
/// and per-pod trunks, plus the routing rule that turns `(src, dst)`
/// into a link path.
#[derive(Clone, Debug)]
pub struct Topology {
    kind: TopologyKind,
    hosts: usize,
    pod_size: usize,
    links: Vec<Link>,
    /// Per host: the link from the host into its switch.
    up: Vec<usize>,
    /// Per host: the link from its switch back to the host.
    down: Vec<usize>,
    /// Per pod: the trunk leaving the pod (empty for single-domain).
    trunk_up: Vec<usize>,
    /// Per pod: the trunk entering the pod (empty for single-domain;
    /// for the dumbbell the two directed trunks serve both roles).
    trunk_down: Vec<usize>,
}

impl Topology {
    /// Every host on one non-blocking switch at `intra_bw`; one-hop
    /// latency `intra_latency`.
    pub fn single_domain(hosts: usize, ic: &Interconnect) -> Self {
        assert!(hosts >= 1, "topology needs at least one host");
        let mut links = Vec::with_capacity(2 * hosts);
        let (mut up, mut down) = (Vec::with_capacity(hosts), Vec::with_capacity(hosts));
        for h in 0..hosts {
            up.push(links.len());
            links.push(Link::new(ic.intra_bw, ic.intra_latency / 2.0, format!("up:{h}")));
            down.push(links.len());
            links.push(Link::new(ic.intra_bw, ic.intra_latency / 2.0, format!("down:{h}")));
        }
        Topology {
            kind: TopologyKind::SingleDomain,
            hosts,
            pod_size: hosts,
            links,
            up,
            down,
            trunk_up: Vec::new(),
            trunk_down: Vec::new(),
        }
    }

    /// Pods of `ic.domain_size` hosts behind a spine; each pod's trunk
    /// carries `pod_size × inter_bw` (the pod's aggregate slow-network
    /// injection bandwidth), and a cross-pod path's latency totals
    /// `inter_latency`.
    pub fn two_tier(hosts: usize, ic: &Interconnect) -> Self {
        let pod_size = ic.domain_size.max(1).min(hosts.max(1));
        let pods = hosts.div_ceil(pod_size);
        let trunk_bw = pod_size as f64 * ic.inter_bw;
        let trunk_latency = ((ic.inter_latency - ic.intra_latency) / 2.0).max(0.0);
        let mut t = Self::single_domain(hosts, ic);
        t.kind = TopologyKind::TwoTier;
        t.pod_size = pod_size;
        for p in 0..pods {
            t.trunk_up.push(t.links.len());
            t.links.push(Link::new(trunk_bw, trunk_latency, format!("trunk-up:{p}")));
            t.trunk_down.push(t.links.len());
            t.links.push(Link::new(trunk_bw, trunk_latency, format!("trunk-down:{p}")));
        }
        t
    }

    /// Two halves joined by a single directed trunk pair whose capacity
    /// is the half's aggregate injection bandwidth divided by
    /// `oversubscription` — the contention fixture.  `oversubscription
    /// = 1.0` is a full-bisection dumbbell; larger values starve
    /// cross-half traffic.
    pub fn dumbbell(hosts: usize, ic: &Interconnect, oversubscription: f64) -> Self {
        assert!(hosts >= 2 && hosts % 2 == 0, "dumbbell needs an even host count");
        assert!(oversubscription >= 1.0, "oversubscription is a ratio >= 1");
        let half = hosts / 2;
        let trunk_bw = half as f64 * ic.inter_bw / oversubscription;
        let trunk_latency = ((ic.inter_latency - ic.intra_latency) / 2.0).max(0.0);
        let mut t = Self::single_domain(hosts, ic);
        t.kind = TopologyKind::Dumbbell;
        t.pod_size = half;
        // one directed trunk per crossing direction; a cross path uses
        // exactly one of them, so it serves as both pods' up/down trunk
        for p in 0..2 {
            let l = t.links.len();
            t.links.push(Link::new(trunk_bw, 2.0 * trunk_latency, format!("trunk:{p}>{}", 1 - p)));
            t.trunk_up.push(l);
        }
        t.trunk_down = vec![t.trunk_up[1], t.trunk_up[0]];
        t
    }

    /// Derate each host's access links by up to `amount` (a fraction in
    /// `[0, 1)`), drawn per host from a seeded RNG — the deterministic
    /// straggler model.  Same seed, same topology, bit-identical
    /// timelines.
    pub fn with_host_jitter(mut self, seed: u64, amount: f64) -> Self {
        assert!((0.0..1.0).contains(&amount), "jitter amount must be in [0, 1)");
        let mut rng = Rng::new(seed);
        for h in 0..self.hosts {
            let derate = 1.0 - amount * rng.next_f64();
            self.links[self.up[h]].bw *= derate;
            self.links[self.down[h]].bw *= derate;
        }
        self
    }

    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    pub fn hosts(&self) -> usize {
        self.hosts
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Hosts per pod (the whole machine for single-domain).
    pub fn pod_size(&self) -> usize {
        self.pod_size
    }

    pub fn pod_of(&self, host: usize) -> usize {
        assert!(host < self.hosts, "host {host} out of range ({})", self.hosts);
        host / self.pod_size
    }

    /// The directed link path from `src` to `dst` (both hosts).
    pub fn path(&self, src: usize, dst: usize) -> Vec<usize> {
        assert!(src < self.hosts && dst < self.hosts, "path endpoints out of range");
        assert_ne!(src, dst, "a flow needs distinct endpoints");
        let (sp, dp) = (self.pod_of(src), self.pod_of(dst));
        if sp == dp || self.kind == TopologyKind::SingleDomain {
            return vec![self.up[src], self.down[dst]];
        }
        match self.kind {
            TopologyKind::SingleDomain => unreachable!(),
            TopologyKind::TwoTier => {
                vec![self.up[src], self.trunk_up[sp], self.trunk_down[dp], self.down[dst]]
            }
            // the dumbbell trunk is a single directed hop
            TopologyKind::Dumbbell => vec![self.up[src], self.trunk_up[sp], self.down[dst]],
        }
    }

    /// Total propagation latency along a path.
    pub fn path_latency(&self, path: &[usize]) -> f64 {
        path.iter().map(|&l| self.links[l].latency).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::chips;

    fn ic() -> Interconnect {
        chips::h100().interconnect
    }

    #[test]
    fn single_domain_paths_pay_intra_latency() {
        let t = Topology::single_domain(16, &ic());
        assert_eq!(t.kind(), TopologyKind::SingleDomain);
        let p = t.path(3, 11);
        assert_eq!(p.len(), 2);
        assert_eq!(t.path_latency(&p), ic().intra_latency);
    }

    #[test]
    fn two_tier_pods_and_trunks() {
        let t = Topology::two_tier(64, &ic()); // domain_size 8 -> 8 pods
        assert_eq!(t.pod_size(), 8);
        assert_eq!(t.pod_of(7), 0);
        assert_eq!(t.pod_of(8), 1);
        // intra-pod: two hops, intra latency
        let p = t.path(0, 7);
        assert_eq!(p.len(), 2);
        assert_eq!(t.path_latency(&p), ic().intra_latency);
        // cross-pod: four hops totalling inter latency
        let p = t.path(0, 63);
        assert_eq!(p.len(), 4);
        assert!((t.path_latency(&p) - ic().inter_latency).abs() < 1e-15);
        // trunk carries the pod's aggregate injection bandwidth
        let trunk = &t.links()[p[1]];
        assert_eq!(trunk.bw, 8.0 * ic().inter_bw);
    }

    #[test]
    fn dumbbell_oversubscription_shrinks_the_trunk() {
        let full = Topology::dumbbell(16, &ic(), 1.0);
        let starved = Topology::dumbbell(16, &ic(), 4.0);
        let trunk_bw = |t: &Topology| t.links()[t.path(0, 15)[1]].bw;
        assert_eq!(trunk_bw(&full), 8.0 * ic().inter_bw);
        assert_eq!(trunk_bw(&starved), 2.0 * ic().inter_bw);
        // cross paths use one directed trunk; same-half paths skip it
        assert_eq!(full.path(0, 15).len(), 3);
        assert_eq!(full.path(0, 7).len(), 2);
        assert_ne!(full.path(0, 8)[1], full.path(8, 0)[1], "directions are separate links");
    }

    #[test]
    fn jitter_is_seeded_and_bounded() {
        let base = Topology::single_domain(32, &ic());
        let a = base.clone().with_host_jitter(9, 0.2);
        let b = base.clone().with_host_jitter(9, 0.2);
        let c = base.clone().with_host_jitter(10, 0.2);
        let mut differs_across_seeds = false;
        for l in 0..base.links().len() {
            let (bw0, bw_a) = (base.links()[l].bw, a.links()[l].bw);
            assert_eq!(bw_a.to_bits(), b.links()[l].bw.to_bits(), "same seed must replay");
            assert!(bw_a <= bw0 && bw_a >= bw0 * 0.8, "derate out of range: {bw_a} vs {bw0}");
            if bw_a.to_bits() != c.links()[l].bw.to_bits() {
                differs_across_seeds = true;
            }
        }
        assert!(differs_across_seeds, "different seeds must jitter differently");
    }
}
