//! Collective-algorithm lowering: turn one collective over a rank set
//! into the per-link flow set the fluid engine executes.
//!
//! The lowerings mirror the textbook algorithms the analytic
//! [`crate::perfmodel::comms`] model prices, so that on a
//! contention-free [`Topology::single_domain`] the simulated times land
//! within tolerance of the closed forms (the contract
//! `rust/tests/netsim_validation.rs` pins):
//!
//! * **Ring** all-gather / reduce-scatter: `n-1` rounds of `bytes/n`
//!   chunks around the ring; all-reduce is the reduce-scatter ring
//!   followed by the all-gather ring (`2(n-1)` rounds).  Rounds are
//!   cut-through pipelined: only round-0 flows pay wire latency (see
//!   [`super::sim::FlowSpec::pays_latency`]).
//! * **AllToAll** is a single shot: every rank sends `bytes/(n-1)` to
//!   every other rank simultaneously, so each access link carries the
//!   full `bytes` — the per-link factor is 1, not the ring's
//!   `(n-1)/n`, which is exactly the `payload_factor` fix this
//!   simulator grounds (all-to-all-v routing is data-dependent, so no
//!   uniform `1/n` stay-local share can be assumed).
//! * **Broadcast** is a pipelined chain (cut-through: all hops drain
//!   concurrently on disjoint links), **P2P** a store-and-forward
//!   chain — one hop per stage boundary, strictly serialized.
//! * **Tree** broadcasts/reduces along a binomial tree (`log2 n` full-
//!   payload levels); gather-type collectives fall back to the ring,
//!   which is bandwidth-optimal for them.
//! * **Hierarchical** mirrors `comms::hierarchical` phase for phase:
//!   intra-pod rings on the full payload, then per-slot inter-pod
//!   exchanges on `bytes/within` (every intra-pod slot drives its own
//!   cross-pod ring, so the trunk's aggregate bandwidth is actually
//!   used), with a barrier between phases — the analytic model sums
//!   phases, so the lowering sequences them.
//!
//! [`AlgoChoice::Auto`] picks Hierarchical when the ranks span more
//! than one pod, Ring otherwise.

use anyhow::Result;

use crate::perfmodel::comms::Collective;

use super::sim::{simulate_flows, FlowSpec, Timeline};
use super::topo::Topology;

/// Which lowering family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoChoice {
    Ring,
    Tree,
    Hierarchical,
    /// Hierarchical when the ranks span pods, Ring otherwise.
    Auto,
}

fn push_flow(
    flows: &mut Vec<FlowSpec>,
    src: usize,
    dst: usize,
    bytes: f64,
    deps: Vec<usize>,
    pays_latency: bool,
) -> usize {
    flows.push(FlowSpec { src, dst, bytes, deps, pays_latency });
    flows.len() - 1
}

/// `rounds` rounds of `chunk`-byte neighbor exchanges around the ring
/// of `ranks`.  Round 0 waits on `deps0` (the phase barrier) and pays
/// latency; later rounds are released by the sender having forwarded
/// its previous chunk and received its neighbor's.  Returns the
/// last-round flow ids (the next phase's barrier).
fn ring_rounds(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    chunk: f64,
    rounds: usize,
    deps0: &[usize],
) -> Vec<usize> {
    let n = ranks.len();
    if n < 2 || rounds == 0 {
        return deps0.to_vec();
    }
    let base = flows.len();
    for r in 0..rounds {
        for i in 0..n {
            let deps = if r == 0 {
                deps0.to_vec()
            } else {
                let prev = base + (r - 1) * n;
                vec![prev + i, prev + (i + n - 1) % n]
            };
            push_flow(flows, ranks[i], ranks[(i + 1) % n], chunk, deps, r == 0);
        }
    }
    (0..n).map(|i| base + (rounds - 1) * n + i).collect()
}

/// Single-shot all-to-all: every rank sends `per_peer` bytes to every
/// other rank, all concurrently.
fn alltoall_shot(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    per_peer: f64,
    deps0: &[usize],
) -> Vec<usize> {
    let n = ranks.len();
    if n < 2 {
        return deps0.to_vec();
    }
    let mut out = Vec::with_capacity(n * (n - 1));
    for i in 0..n {
        for j in 0..n {
            if i != j {
                out.push(push_flow(flows, ranks[i], ranks[j], per_peer, deps0.to_vec(), true));
            }
        }
    }
    out
}

/// Pipelined broadcast chain: every hop starts at once (cut-through on
/// disjoint links), so the makespan is one hop's `bytes/bw + latency`.
fn broadcast_chain(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    bytes: f64,
    deps0: &[usize],
) -> Vec<usize> {
    ranks
        .windows(2)
        .map(|w| push_flow(flows, w[0], w[1], bytes, deps0.to_vec(), true))
        .collect()
}

/// Store-and-forward point-to-point chain: hop `k` waits for hop
/// `k-1` — the pipeline stage-boundary pattern.
fn p2p_chain(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    bytes: f64,
    deps0: &[usize],
) -> Vec<usize> {
    let mut prev = deps0.to_vec();
    for w in ranks.windows(2) {
        prev = vec![push_flow(flows, w[0], w[1], bytes, prev, true)];
    }
    prev
}

/// Binomial-tree broadcast from `ranks[0]`: level `l` doubles the
/// covered prefix, each transfer carrying the full payload.
fn tree_broadcast(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    bytes: f64,
    deps0: &[usize],
) -> Vec<usize> {
    let n = ranks.len();
    // delivered[i]: the flow that delivered the payload to ranks[i]
    let mut delivered: Vec<Option<usize>> = vec![None; n];
    let mut leaves = Vec::new();
    let mut span = 1;
    while span < n {
        for i in 0..span.min(n) {
            let j = i + span;
            if j >= n {
                continue;
            }
            let deps = match delivered[i] {
                Some(f) => vec![f],
                None => deps0.to_vec(),
            };
            let f = push_flow(flows, ranks[i], ranks[j], bytes, deps, true);
            delivered[j] = Some(f);
            leaves.push(f);
        }
        span *= 2;
    }
    // only the final-level flows gate the next phase, but returning
    // every tree edge keeps the barrier conservative and correct
    leaves
}

/// Binomial-tree reduction onto `ranks[0]` (the broadcast mirrored).
fn tree_reduce(
    flows: &mut Vec<FlowSpec>,
    ranks: &[usize],
    bytes: f64,
    deps0: &[usize],
) -> Vec<usize> {
    let n = ranks.len();
    let mut sent: Vec<Option<usize>> = vec![None; n];
    let mut last = deps0.to_vec();
    let mut span = n.next_power_of_two() / 2;
    while span >= 1 {
        let mut level = Vec::new();
        for i in 0..span {
            let j = i + span;
            if j >= n {
                continue;
            }
            // a rank sends once it has absorbed everything below it
            let mut deps: Vec<usize> = deps0.to_vec();
            if let Some(f) = sent[j] {
                deps.push(f);
            }
            let f = push_flow(flows, ranks[j], ranks[i], bytes, deps, true);
            sent[i] = Some(f);
            level.push(f);
        }
        if !level.is_empty() {
            last = level;
        }
        span /= 2;
    }
    last
}

/// Group `ranks` by pod, preserving first-appearance order.  Errors
/// when the pods are unevenly filled (the hierarchical phase structure
/// needs one slot per intra-pod position).
fn pod_groups(topo: &Topology, ranks: &[usize]) -> Result<Vec<Vec<usize>>> {
    let mut order: Vec<usize> = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for &r in ranks {
        let p = topo.pod_of(r);
        match order.iter().position(|&q| q == p) {
            Some(k) => groups[k].push(r),
            None => {
                order.push(p);
                groups.push(vec![r]);
            }
        }
    }
    let w = groups[0].len();
    anyhow::ensure!(
        groups.iter().all(|g| g.len() == w),
        "hierarchical lowering needs equally filled pods (got {:?})",
        groups.iter().map(|g| g.len()).collect::<Vec<_>>()
    );
    Ok(groups)
}

/// Lower one collective over `ranks` into `flows` (appending; indices
/// are absolute, so several instances can share one flow set).
pub fn lower_collective_into(
    flows: &mut Vec<FlowSpec>,
    topo: &Topology,
    algo: AlgoChoice,
    c: Collective,
    ranks: &[usize],
    bytes: f64,
) -> Result<()> {
    let n = ranks.len();
    anyhow::ensure!(bytes >= 0.0 && bytes.is_finite(), "collective payload must be finite");
    {
        let mut seen = ranks.to_vec();
        seen.sort_unstable();
        seen.dedup();
        anyhow::ensure!(seen.len() == n, "collective ranks must be distinct");
    }
    if n < 2 {
        return Ok(());
    }
    let nf = n as f64;
    let spans_pods = ranks.iter().any(|&r| topo.pod_of(r) != topo.pod_of(ranks[0]));
    let algo = match algo {
        AlgoChoice::Auto if spans_pods => AlgoChoice::Hierarchical,
        AlgoChoice::Auto => AlgoChoice::Ring,
        AlgoChoice::Hierarchical if !spans_pods => AlgoChoice::Ring,
        other => other,
    };
    match algo {
        AlgoChoice::Ring | AlgoChoice::Tree => {
            // tree only changes the rooted collectives; the gather-type
            // collectives keep the bandwidth-optimal ring
            match c {
                Collective::AllGather | Collective::ReduceScatter => {
                    ring_rounds(flows, ranks, bytes / nf, n - 1, &[]);
                }
                Collective::AllReduce => {
                    if algo == AlgoChoice::Tree {
                        let up = tree_reduce(flows, ranks, bytes, &[]);
                        tree_broadcast(flows, ranks, bytes, &up);
                    } else {
                        ring_rounds(flows, ranks, bytes / nf, 2 * (n - 1), &[]);
                    }
                }
                Collective::AllToAll => {
                    alltoall_shot(flows, ranks, bytes / (nf - 1.0), &[]);
                }
                Collective::Broadcast => {
                    if algo == AlgoChoice::Tree {
                        tree_broadcast(flows, ranks, bytes, &[]);
                    } else {
                        broadcast_chain(flows, ranks, bytes, &[]);
                    }
                }
                Collective::P2P => {
                    p2p_chain(flows, ranks, bytes, &[]);
                }
            }
        }
        AlgoChoice::Hierarchical => {
            let groups = pod_groups(topo, ranks)?;
            let (a, w) = (groups.len(), groups[0].len());
            let (af, wf) = (a as f64, w as f64);
            let slot_ranks =
                |s: usize| groups.iter().map(|g| g[s]).collect::<Vec<usize>>();
            match c {
                Collective::AllReduce => {
                    // intra reduce-scatter, per-slot inter all-reduce on
                    // the 1/within shard, intra all-gather — the same
                    // three phases comms::hierarchical sums
                    let mut b1 = Vec::new();
                    for g in &groups {
                        b1.extend(ring_rounds(flows, g, bytes / wf, w.saturating_sub(1), &[]));
                    }
                    let shard = bytes / wf;
                    let mut b2 = Vec::new();
                    for s in 0..w {
                        b2.extend(ring_rounds(
                            flows,
                            &slot_ranks(s),
                            shard / af,
                            2 * (a - 1),
                            &b1,
                        ));
                    }
                    for g in &groups {
                        ring_rounds(flows, g, bytes / wf, w.saturating_sub(1), &b2);
                    }
                }
                Collective::AllGather | Collective::ReduceScatter => {
                    let mut b1 = Vec::new();
                    for g in &groups {
                        b1.extend(ring_rounds(flows, g, bytes / wf, w.saturating_sub(1), &[]));
                    }
                    let shard = bytes / wf;
                    for s in 0..w {
                        ring_rounds(flows, &slot_ranks(s), shard / af, a - 1, &b1);
                    }
                }
                Collective::AllToAll => {
                    let mut b1 = Vec::new();
                    if w > 1 {
                        for g in &groups {
                            b1.extend(alltoall_shot(flows, g, bytes / (wf - 1.0), &[]));
                        }
                    }
                    let shard = bytes / wf;
                    for s in 0..w {
                        alltoall_shot(flows, &slot_ranks(s), shard / (af - 1.0), &b1);
                    }
                }
                Collective::Broadcast => {
                    // mirror the analytic decomposition: full payload
                    // within the root's pod, 1/within shards across
                    let b1 = broadcast_chain(flows, &groups[0], bytes, &[]);
                    for s in 0..w {
                        broadcast_chain(flows, &slot_ranks(s), bytes / wf, &b1);
                    }
                }
                Collective::P2P => {
                    p2p_chain(flows, ranks, bytes, &[]);
                }
            }
        }
        AlgoChoice::Auto => unreachable!("resolved above"),
    }
    Ok(())
}

/// Lower one collective into a fresh flow set.
pub fn lower_collective(
    topo: &Topology,
    algo: AlgoChoice,
    c: Collective,
    ranks: &[usize],
    bytes: f64,
) -> Result<Vec<FlowSpec>> {
    let mut flows = Vec::new();
    lower_collective_into(&mut flows, topo, algo, c, ranks, bytes)?;
    Ok(flows)
}

/// Lower and run one collective; the timeline's makespan is its
/// simulated completion time.
pub fn simulate_collective(
    topo: &Topology,
    algo: AlgoChoice,
    c: Collective,
    ranks: &[usize],
    bytes: f64,
) -> Result<Timeline> {
    simulate_flows(topo, &lower_collective(topo, algo, c, ranks, bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::chips::{self, Interconnect};
    use crate::perfmodel::comms;

    fn flat_ic(n: usize) -> Interconnect {
        Interconnect { domain_size: n, ..chips::h100().interconnect }
    }

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-12)
    }

    #[test]
    fn ring_collectives_match_the_analytic_bandwidth_term() {
        let ic = flat_ic(64);
        let topo = Topology::single_domain(64, &ic);
        let ranks: Vec<usize> = (0..64).collect();
        let bytes = 4e9;
        for c in [Collective::AllGather, Collective::ReduceScatter, Collective::AllReduce] {
            let tl = simulate_collective(&topo, AlgoChoice::Ring, c, &ranks, bytes).unwrap();
            let analytic = comms::intra_domain(c, bytes, 64, &ic);
            assert!(
                rel_err(tl.makespan_s, analytic) < 0.05,
                "{c:?}: sim {} vs analytic {analytic}",
                tl.makespan_s
            );
        }
    }

    #[test]
    fn alltoall_uplink_carries_the_full_payload() {
        // the payload_factor fix's ground truth: each access link moves
        // `bytes`, so the time is bytes/bw + latency — factor 1.0
        let ic = flat_ic(8);
        let topo = Topology::single_domain(8, &ic);
        let ranks: Vec<usize> = (0..8).collect();
        let bytes = 9e9;
        let tl =
            simulate_collective(&topo, AlgoChoice::Ring, Collective::AllToAll, &ranks, bytes)
                .unwrap();
        let implied_factor = (tl.makespan_s - ic.intra_latency) * ic.intra_bw / bytes;
        assert!(
            (implied_factor - 1.0).abs() < 1e-9,
            "implied per-link factor {implied_factor}"
        );
        // and every rank's up link carried exactly `bytes`
        for h in 0..8 {
            let up = topo.path(h, (h + 1) % 8)[0];
            assert!((tl.link_bytes[up] - bytes).abs() < 1.0);
        }
    }

    #[test]
    fn tree_broadcast_pays_log_depth() {
        let ic = flat_ic(16);
        let topo = Topology::single_domain(16, &ic);
        let ranks: Vec<usize> = (0..16).collect();
        let tl = simulate_collective(&topo, AlgoChoice::Tree, Collective::Broadcast, &ranks, 1e9)
            .unwrap();
        // 4 serialized levels of full-payload transfers
        let level = ic.intra_latency + 1e9 / ic.intra_bw;
        assert!(rel_err(tl.makespan_s, 4.0 * level) < 0.05, "{}", tl.makespan_s);
        // everyone received the payload exactly once
        let received: f64 = (1..16).map(|h| tl.link_bytes[topo.path(0, h)[1]]).sum();
        assert!((received - 15.0 * 1e9).abs() < 1.0);
    }

    #[test]
    fn tree_allreduce_completes_and_covers_all_ranks() {
        let ic = flat_ic(10); // non-power-of-two
        let topo = Topology::single_domain(10, &ic);
        let ranks: Vec<usize> = (0..10).collect();
        let tl =
            simulate_collective(&topo, AlgoChoice::Tree, Collective::AllReduce, &ranks, 1e9)
                .unwrap();
        assert!(tl.makespan_s > 0.0);
        // every non-root rank both sent (reduce) and received (bcast)
        for h in 1..10 {
            assert!(tl.link_bytes[topo.path(h, 0)[0]] > 0.0, "rank {h} never sent");
            assert!(tl.link_bytes[topo.path(0, h)[1]] > 0.0, "rank {h} never received");
        }
    }

    #[test]
    fn hierarchical_matches_the_analytic_phase_sum_on_two_tier() {
        let ic = chips::h100().interconnect; // domain_size 8
        let topo = Topology::two_tier(32, &ic);
        let ranks: Vec<usize> = (0..32).collect();
        let bytes = 4e9;
        for c in [Collective::AllReduce, Collective::AllGather, Collective::AllToAll] {
            let tl = simulate_collective(&topo, AlgoChoice::Auto, c, &ranks, bytes).unwrap();
            // the analytic hierarchical bound with the AllToAll factor
            // corrected to 1: compare loosely — the bandwidth terms
            // dominate at 4 GB and must agree within 10%
            let analytic = comms::hierarchical(c, bytes, 32, &ic);
            assert!(
                rel_err(tl.makespan_s, analytic) < 0.10,
                "{c:?}: sim {} vs analytic {analytic}",
                tl.makespan_s
            );
        }
    }

    #[test]
    fn auto_resolves_by_pod_span() {
        let ic = chips::h100().interconnect;
        let topo = Topology::two_tier(16, &ic);
        let intra: Vec<usize> = (0..8).collect();
        let cross: Vec<usize> = (0..16).collect();
        // intra-pod auto == ring lowering, flow for flow
        let a = lower_collective(&topo, AlgoChoice::Auto, Collective::AllReduce, &intra, 1e9)
            .unwrap();
        let r = lower_collective(&topo, AlgoChoice::Ring, Collective::AllReduce, &intra, 1e9)
            .unwrap();
        assert_eq!(a.len(), r.len());
        // cross-pod auto grows the hierarchical phase structure
        let h = lower_collective(&topo, AlgoChoice::Auto, Collective::AllReduce, &cross, 1e9)
            .unwrap();
        assert!(h.len() > r.len());
        // and rejects duplicate ranks
        assert!(lower_collective(&topo, AlgoChoice::Ring, Collective::AllReduce, &[0, 0], 1.0)
            .is_err());
    }
}
