//! The InvocationContext stack (paper §4.3, Figure 3).
//!
//! `InvocationContext::scope("child", |...| ...)` pushes a child context
//! (splitting the PRNG key, opening a fresh output collection), runs the
//! closure, then pops — merging the child's summaries into the parent
//! under `child/`.  A thread-local ambient pointer lets *any* code record
//! summaries without holding a module reference ("contexts contain
//! references to modules, but not vice-versa").

use std::cell::RefCell;

use crate::util::rng::Rng;

use super::summary::{OutputCollection, SummaryValue};

/// One frame of the invocation stack.
struct Frame {
    name: String,
    rng: Rng,
    outputs: OutputCollection,
}

/// The invocation context: a stack of frames rooted at a named root
/// module (typically "trainer").
pub struct InvocationContext {
    frames: Vec<Frame>,
}

thread_local! {
    static AMBIENT: RefCell<Option<*mut InvocationContext>> = const { RefCell::new(None) };
}

impl InvocationContext {
    pub fn new(root: &str, seed: u64) -> Self {
        InvocationContext {
            frames: vec![Frame {
                name: root.to_string(),
                rng: Rng::new(seed),
                outputs: OutputCollection::new(),
            }],
        }
    }

    /// Dotted path of the current frame (e.g. `trainer.model.decoder`).
    pub fn path(&self) -> String {
        self.frames
            .iter()
            .map(|f| f.name.as_str())
            .collect::<Vec<_>>()
            .join(".")
    }

    pub fn depth(&self) -> usize {
        self.frames.len()
    }

    /// Split an independent PRNG off the current frame (Figure 3's
    /// "split PRNG key").
    pub fn prng(&mut self) -> Rng {
        self.frames.last_mut().expect("context has a root").rng.split()
    }

    /// Record a scalar summary in the current frame.
    pub fn scalar(&mut self, key: &str, value: f64) {
        self.frames.last_mut().unwrap().outputs.scalar(key, value);
    }

    /// Record an accumulating counter in the current frame.
    pub fn counter(&mut self, key: &str, value: f64) {
        self.frames.last_mut().unwrap().outputs.counter(key, value);
    }

    pub fn add(&mut self, key: &str, value: SummaryValue) {
        self.frames.last_mut().unwrap().outputs.add(key, value);
    }

    /// Push a child frame, run `f`, pop and merge outputs into the parent
    /// under `name/` — the core Figure-3 mechanic.
    pub fn scope<T, F: FnOnce(&mut InvocationContext) -> T>(&mut self, name: &str, f: F) -> T {
        let child_rng = self.prng();
        self.frames.push(Frame {
            name: name.to_string(),
            rng: child_rng,
            outputs: OutputCollection::new(),
        });
        let result = f(self);
        let frame = self.frames.pop().expect("scope pushed a frame");
        self.frames
            .last_mut()
            .unwrap()
            .outputs
            .merge_child(&frame.name, frame.outputs);
        result
    }

    /// Root output collection (drained by the trainer's summary writer).
    pub fn outputs(&self) -> &OutputCollection {
        &self.frames[0].outputs
    }

    pub fn outputs_mut(&mut self) -> &mut OutputCollection {
        &mut self.frames[0].outputs
    }

    /// Traverse the context stack looking for a summary already recorded
    /// by an ancestor — the "retrieve shared state" path of Figure 3 that
    /// features like tied weights use while preserving encapsulation.
    pub fn lookup_up_stack(&self, key: &str) -> Option<&SummaryValue> {
        self.frames.iter().rev().find_map(|f| f.outputs.get(key))
    }

    /// Install this context as the thread-ambient one for the duration of
    /// `f` — so free functions ([`in_context`]) can reach it without a
    /// module reference (the optax/custom_vjp integration point of §4.3).
    pub fn enter<T, F: FnOnce() -> T>(&mut self, f: F) -> T {
        let ptr = self as *mut InvocationContext;
        AMBIENT.with(|a| {
            let prev = a.replace(Some(ptr));
            let result = f();
            a.replace(prev);
            result
        })
    }
}

/// Run `f` with the current ambient context, if any.  Free functions use
/// this to record summaries without any module reference.
pub fn in_context<T, F: FnOnce(&mut InvocationContext) -> T>(f: F) -> Option<T> {
    AMBIENT.with(|a| {
        let ptr = (*a.borrow())?;
        // Safety: the pointer is valid for the dynamic extent of `enter`,
        // and contexts are thread-local (never shared across threads).
        let ctx = unsafe { &mut *ptr };
        Some(f(ctx))
    })
}

/// Path of the ambient context, if inside one.
pub fn current_context_path() -> Option<String> {
    in_context(|ctx| ctx.path())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_merges_with_prefix() {
        let mut ctx = InvocationContext::new("trainer", 0);
        ctx.scope("model", |ctx| {
            ctx.scalar("loss", 3.0);
            ctx.scope("decoder", |ctx| {
                ctx.scalar("norm", 1.5);
            });
        });
        assert_eq!(ctx.outputs().get("model/loss"), Some(&SummaryValue::Scalar(3.0)));
        assert_eq!(
            ctx.outputs().get("model/decoder/norm"),
            Some(&SummaryValue::Scalar(1.5))
        );
    }

    #[test]
    fn path_tracks_stack() {
        let mut ctx = InvocationContext::new("trainer", 0);
        assert_eq!(ctx.path(), "trainer");
        ctx.scope("model", |ctx| {
            ctx.scope("layer0", |ctx| {
                assert_eq!(ctx.path(), "trainer.model.layer0");
                assert_eq!(ctx.depth(), 3);
            });
        });
        assert_eq!(ctx.depth(), 1);
    }

    #[test]
    fn prng_splits_deterministic_and_independent() {
        let mut c1 = InvocationContext::new("t", 7);
        let mut c2 = InvocationContext::new("t", 7);
        let a = c1.scope("m", |c| c.prng().next_u64());
        let b = c2.scope("m", |c| c.prng().next_u64());
        assert_eq!(a, b); // same seed, same path => same stream
        let c = c1.scope("m", |c| c.prng().next_u64());
        assert_ne!(a, c); // parent stream advanced => different child key
    }

    #[test]
    fn ambient_context_reachable_from_free_function() {
        fn free_function_records_summary() {
            in_context(|ctx| ctx.counter("free_calls", 1.0));
        }
        let mut ctx = InvocationContext::new("trainer", 0);
        ctx.enter(|| {
            free_function_records_summary();
            free_function_records_summary();
        });
        assert_eq!(
            ctx.outputs().get("free_calls"),
            Some(&SummaryValue::Counter(2.0))
        );
    }

    #[test]
    fn ambient_absent_outside_enter() {
        assert!(current_context_path().is_none());
        let mut ctx = InvocationContext::new("root", 0);
        let path = ctx.enter(current_context_path);
        assert_eq!(path.as_deref(), Some("root"));
        assert!(current_context_path().is_none());
    }

    #[test]
    fn lookup_up_stack_finds_ancestor_state() {
        let mut ctx = InvocationContext::new("trainer", 0);
        ctx.scalar("shared/emb_scale", 0.125);
        let found = ctx.scope("model", |ctx| {
            ctx.scope("lm_head", |ctx| ctx.lookup_up_stack("shared/emb_scale").cloned())
        });
        assert_eq!(found, Some(SummaryValue::Scalar(0.125)));
    }

    #[test]
    fn counters_accumulate_across_scopes() {
        let mut ctx = InvocationContext::new("t", 0);
        for _ in 0..3 {
            ctx.scope("step", |ctx| ctx.counter("tokens", 128.0));
        }
        assert_eq!(
            ctx.outputs().get("step/tokens"),
            Some(&SummaryValue::Counter(384.0))
        );
    }
}
