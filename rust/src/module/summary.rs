//! Output/summary collections gathered through the InvocationContext.

use std::collections::BTreeMap;

/// A summary value recorded by a module.
#[derive(Clone, Debug, PartialEq)]
pub enum SummaryValue {
    Scalar(f64),
    Int(i64),
    Text(String),
    /// Accumulating counter (merged by addition).
    Counter(f64),
}

impl SummaryValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            SummaryValue::Scalar(x) | SummaryValue::Counter(x) => Some(*x),
            SummaryValue::Int(i) => Some(*i as f64),
            SummaryValue::Text(_) => None,
        }
    }
}

/// A path-keyed collection of summaries.  Child collections merge into the
/// parent when a context pops, path-prefixed by the child's name — exactly
/// the data store semantics of Figure 3.
#[derive(Clone, Debug, Default)]
pub struct OutputCollection {
    entries: BTreeMap<String, SummaryValue>,
}

impl OutputCollection {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &str, value: SummaryValue) {
        match (self.entries.get_mut(key), &value) {
            (Some(SummaryValue::Counter(acc)), SummaryValue::Counter(x)) => *acc += x,
            _ => {
                self.entries.insert(key.to_string(), value);
            }
        }
    }

    pub fn scalar(&mut self, key: &str, value: f64) {
        self.add(key, SummaryValue::Scalar(value));
    }

    pub fn counter(&mut self, key: &str, value: f64) {
        self.add(key, SummaryValue::Counter(value));
    }

    pub fn get(&self, key: &str) -> Option<&SummaryValue> {
        self.entries.get(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &SummaryValue)> {
        self.entries.iter()
    }

    /// Merge `child` into self with `prefix/` prepended to every key
    /// (context pop).
    pub fn merge_child(&mut self, prefix: &str, child: OutputCollection) {
        for (k, v) in child.entries {
            let key = if prefix.is_empty() { k } else { format!("{prefix}/{k}") };
            self.add(&key, v);
        }
    }

    pub fn drain(&mut self) -> BTreeMap<String, SummaryValue> {
        std::mem::take(&mut self.entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_overwrites() {
        let mut c = OutputCollection::new();
        c.scalar("loss", 2.0);
        c.scalar("loss", 1.0);
        assert_eq!(c.get("loss"), Some(&SummaryValue::Scalar(1.0)));
    }

    #[test]
    fn counters_accumulate() {
        let mut c = OutputCollection::new();
        c.counter("tokens", 10.0);
        c.counter("tokens", 5.0);
        assert_eq!(c.get("tokens"), Some(&SummaryValue::Counter(15.0)));
    }

    #[test]
    fn merge_child_prefixes() {
        let mut parent = OutputCollection::new();
        parent.scalar("loss", 1.0);
        let mut child = OutputCollection::new();
        child.scalar("aux", 0.5);
        child.counter("tokens", 3.0);
        parent.merge_child("moe", child);
        assert_eq!(parent.get("moe/aux"), Some(&SummaryValue::Scalar(0.5)));
        assert_eq!(parent.get("moe/tokens"), Some(&SummaryValue::Counter(3.0)));
        assert_eq!(parent.len(), 3);
    }

    #[test]
    fn merge_counters_across_children() {
        // two children reporting the same counter accumulate in the parent
        let mut parent = OutputCollection::new();
        for _ in 0..2 {
            let mut child = OutputCollection::new();
            child.counter("drops", 1.0);
            parent.merge_child("", child);
        }
        assert_eq!(parent.get("drops"), Some(&SummaryValue::Counter(2.0)));
    }
}
