//! Module tree + InvocationContext (paper §4.3).
//!
//! JAX demands pure functions; training is stateful.  AXLearn resolves the
//! tension with an *InvocationContext*: a stack pushed/popped around every
//! child-module invocation that transparently splits PRNG keys, scopes
//! summaries/outputs, and lets code *anywhere* (even code with no module
//! reference — optax-style) reach the current context.
//!
//! On the Rust side the same abstraction organizes the coordinator: the
//! trainer, checkpointer, watchdog, serving engine, and cluster simulator
//! all record summaries through the ambient context, so none of them needs
//! to thread a metrics sink through its signature — the exact
//! encapsulation argument of §4.3.

pub mod context;
pub mod summary;

pub use context::{current_context_path, in_context, InvocationContext};
pub use summary::{OutputCollection, SummaryValue};
