"""L2 layer library tests: shapes, oracles, config-system semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import Config, config_for_function, config_to_lines, replace_config
from compile.kernels import ref
from compile.layers import (
    AttentionLayer,
    CausalLM,
    FeedForward,
    Linear,
    MoE,
    NoPositionalEmbedding,
    RMSNorm,
    RotaryEmbedding,
    TransformerLayer,
)


# ---------------------------------------------------------------------------
# config system (python mirror of rust/src/config)
# ---------------------------------------------------------------------------
class TestConfigSystem:
    def test_set_and_get(self):
        cfg = Linear.default_config().set(input_dim=4, output_dim=8)
        assert cfg.input_dim == 4 and cfg.output_dim == 8

    def test_set_unknown_field_raises(self):
        with pytest.raises(KeyError):
            Linear.default_config().set(bogus=1)

    def test_clone_is_deep(self):
        cfg = TransformerLayer.default_config()
        c2 = cfg.clone()
        c2.self_attention.set(num_heads=7)
        assert cfg.self_attention.num_heads is None

    def test_partial_then_parent_propagates(self):
        """§4.1: parent sets input_dim at instantiation time."""
        cfg = TransformerLayer.default_config().set(input_dim=32)
        cfg.self_attention.set(num_heads=4, head_dim=8)
        cfg.feed_forward.set(hidden_dim=64)
        layer = cfg.instantiate()
        assert layer._children["self_attention"].cfg.input_dim == 32
        assert layer._children["feed_forward"].cfg.input_dim == 32

    def test_callable_hidden_dim(self):
        """scaled_hidden_dim-style deferred configuration."""
        cfg = TransformerLayer.default_config().set(input_dim=30)
        cfg.self_attention.set(num_heads=2, head_dim=8)
        cfg.feed_forward.set(hidden_dim=lambda d: int(d * 8 / 3))
        layer = cfg.instantiate()
        assert layer._children["feed_forward"].cfg.hidden_dim == 80

    def test_replace_config_swaps_ffn_for_moe(self):
        """Figure 1: the MoE drop-in replacement."""
        cfg = TransformerLayer.default_config().set(input_dim=16)
        cfg.self_attention.set(num_heads=2, head_dim=8)
        cfg.feed_forward.set(hidden_dim=32)
        replace_config(
            cfg,
            FeedForward,
            lambda old: MoE.default_config().set(
                input_dim=old.input_dim, hidden_dim=old.hidden_dim, num_experts=2, top_k=1
            ),
        )
        assert cfg.feed_forward.klass is MoE
        layer = cfg.instantiate()  # still instantiates: interface-compatible
        assert isinstance(layer._children["feed_forward"], MoE)

    def test_replace_config_preserves_untargeted_nodes(self):
        cfg = TransformerLayer.default_config().set(input_dim=16)
        before = cfg.self_attention
        replace_config(cfg, MoE, lambda old: old)
        assert cfg.self_attention is before

    def test_config_for_function(self):
        def scale(x, factor=2.0):
            return x * factor

        cfg = config_for_function(scale, factor=3.0)
        f = cfg.instantiate()
        assert f(2.0) == 6.0

    def test_golden_lines_stable(self):
        cfg = Linear.default_config().set(input_dim=4, output_dim=8)
        lines = config_to_lines(cfg)
        assert lines == config_to_lines(cfg.clone())
        assert any("input_dim = 4" in l for l in lines)


# ---------------------------------------------------------------------------
# individual layers vs oracles
# ---------------------------------------------------------------------------
class TestLayers:
    def test_linear_shapes_and_bias(self):
        cfg = Linear.default_config().set(input_dim=6, output_dim=10, use_bias=True)
        layer = cfg.instantiate()
        params = layer.init(jax.random.PRNGKey(0))
        x = jnp.ones((2, 3, 6))
        out = layer(params, x)
        assert out.shape == (2, 3, 10)
        assert params["bias"].shape == (10,)

    def test_rmsnorm_matches_ref(self):
        layer = RMSNorm.default_config().set(input_dim=16).instantiate()
        params = layer.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16))
        np.testing.assert_allclose(
            layer(params, x), ref.rmsnorm_ref(x, params["scale"]), atol=1e-6
        )

    def test_rmsnorm_unit_scale_invariant(self):
        layer = RMSNorm.default_config().set(input_dim=8).instantiate()
        params = layer.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
        out = layer(params, x)
        rms = jnp.sqrt(jnp.mean(out**2, axis=-1))
        np.testing.assert_allclose(rms, jnp.ones_like(rms), atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(shift=st.integers(0, 64), seed=st.integers(0, 1000))
    def test_rope_relative_position_property(self, shift, seed):
        """RoPE scores depend only on relative positions: shifting q and k
        positions by the same amount leaves q.k' inner products unchanged."""
        rope = RotaryEmbedding.default_config().instantiate()
        d = 16
        kq, kk = jax.random.split(jax.random.PRNGKey(seed))
        q = jax.random.normal(kq, (1, 6, 2, d))
        k = jax.random.normal(kk, (1, 6, 2, d))
        pos0 = jnp.arange(6)[None, :]
        q0, k0 = rope.apply_rotary(q, k, pos0)
        q1, k1 = rope.apply_rotary(q, k, pos0 + shift)
        s0 = jnp.einsum("bqhd,bkhd->bhqk", q0, k0)
        s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
        np.testing.assert_allclose(s0, s1, atol=1e-3)

    def test_rope_matches_ref_kernel(self):
        rope = RotaryEmbedding.default_config().instantiate()
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 10, 1, 32))
        pos = jnp.arange(10)[None, :]
        out, _ = rope.apply_rotary(x, x, pos)
        expected = ref.rope_ref(x[:, :, 0, :], jnp.arange(10))
        np.testing.assert_allclose(out[:, :, 0, :], expected, atol=1e-5)

    def test_nope_is_identity(self):
        nope = NoPositionalEmbedding.default_config().instantiate()
        x = jnp.ones((1, 4, 2, 8))
        q, k = nope.apply_rotary(x, x, jnp.zeros((1, 4), jnp.int32))
        assert (q == x).all() and (k == x).all()

    def test_attention_flash_vs_ref_kernel_config(self):
        """Swapping kernel='flash' <-> 'ref' must not change results."""

        def build(kernel):
            cfg = AttentionLayer.default_config().set(
                input_dim=32, num_heads=2, head_dim=16, kernel=kernel
            )
            return cfg.instantiate()

        flash, refl = build("flash"), build("ref")
        params = flash.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
        pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
        np.testing.assert_allclose(
            flash(params, x, pos), refl(params, x, pos), atol=2e-5, rtol=1e-4
        )

    def test_feedforward_swiglu_shape(self):
        ffn = FeedForward.default_config().set(input_dim=8, hidden_dim=16).instantiate()
        params = ffn.init(jax.random.PRNGKey(0))
        out = ffn(params, jnp.ones((2, 3, 8)))
        assert out.shape == (2, 3, 8)

    def test_attention_decode_matches_full_forward(self):
        """Per-row-position decode attention == full causal attention."""
        cfg = AttentionLayer.default_config().set(input_dim=16, num_heads=2, head_dim=8, kernel="ref")
        layer = cfg.instantiate()
        params = layer.init(jax.random.PRNGKey(0))
        b, s = 2, 8
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 16))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        full = layer(params, x, pos)
        # run decode token-by-token
        kc = jnp.zeros((b, s, 2, 8))
        vc = jnp.zeros((b, s, 2, 8))
        outs = []
        for t in range(s):
            o, kc, vc = layer.decode_step(params, x[:, t : t + 1], jnp.full((b,), t), kc, vc)
            outs.append(o[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(dec, full, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------
class TestMoE:
    def _layer(self, e=4, k=2):
        return (
            MoE.default_config()
            .set(input_dim=8, hidden_dim=16, num_experts=e, top_k=k)
            .instantiate()
        )

    def test_output_shape(self):
        layer = self._layer()
        params = layer.init(jax.random.PRNGKey(0))
        out = layer(params, jnp.ones((2, 5, 8)))
        MoE.drain_aux_losses()
        assert out.shape == (2, 5, 8)

    def test_aux_loss_nonnegative_and_drained(self):
        layer = self._layer()
        params = layer.init(jax.random.PRNGKey(0))
        layer(params, jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8)))
        aux = MoE.drain_aux_losses()
        assert float(aux) >= 0.0
        assert float(MoE.drain_aux_losses()) == 0.0  # drained

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), k=st.integers(1, 3))
    def test_topk_equals_dense_reference(self, seed, k):
        """Kernel-style check: dense-dispatch MoE == explicit per-token loop."""
        layer = self._layer(e=4, k=k)
        params = layer.init(jax.random.PRNGKey(seed))
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 6, 8))
        out = layer(params, x)
        MoE.drain_aux_losses()
        tokens = x.reshape(-1, 8)
        probs = jax.nn.softmax(tokens @ params["router"], axis=-1)
        expected = []
        for t in range(tokens.shape[0]):
            w, idx = jax.lax.top_k(probs[t], k)
            w = w / w.sum()
            acc = jnp.zeros(8)
            for wi, ei in zip(w, idx):
                g = jax.nn.silu(tokens[t] @ params["gate"][ei])
                u = tokens[t] @ params["up"][ei]
                acc = acc + wi * ((g * u) @ params["down"][ei])
            expected.append(acc)
        np.testing.assert_allclose(out.reshape(-1, 8), jnp.stack(expected), atol=1e-4, rtol=1e-3)

    def test_single_expert_equals_ffn_semantics(self):
        """E=1, k=1 MoE must reduce to a plain SwiGLU FFN."""
        layer = self._layer(e=1, k=1)
        params = layer.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
        out = layer(params, x)
        MoE.drain_aux_losses()
        g = jax.nn.silu(x @ params["gate"][0])
        u = x @ params["up"][0]
        expected = (g * u) @ params["down"][0]
        np.testing.assert_allclose(out, expected, atol=1e-5)
