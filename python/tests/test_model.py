"""End-to-end L2 tests: train step descends, serving graphs are consistent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelBundle, build_model_config
from compile.layers import MoE, RotaryEmbedding, NoPositionalEmbedding


def _batch(bundle, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, s), 0, bundle.hp["vocab_size"], jnp.int32)
    # next-token prediction targets with the final position masked
    targets = jnp.concatenate([tokens[:, 1:], jnp.full((b, 1), -1, jnp.int32)], axis=1)
    return tokens, targets


@pytest.fixture(scope="module")
def tiny():
    return ModelBundle("tiny", kernel="ref")


@pytest.fixture(scope="module")
def tiny_flash():
    return ModelBundle("tiny", kernel="flash")


class TestTrainStep:
    def test_loss_decreases_on_fixed_batch(self, tiny):
        state = tiny.init(jnp.int32(0))
        tokens, targets = _batch(tiny)
        step = jax.jit(tiny.train_step)
        losses = []
        for _ in range(8):
            out = step(*state, tokens, targets)
            state, loss = out[:-1], out[-1]
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        assert all(np.isfinite(losses))

    def test_initial_loss_near_uniform(self, tiny):
        """Random init => CE ~= log(vocab)."""
        state = tiny.init(jnp.int32(1))
        tokens, targets = _batch(tiny, seed=3)
        out = tiny.train_step(*state, tokens, targets)
        expected = np.log(tiny.hp["vocab_size"])
        assert abs(float(out[-1]) - expected) < 1.0

    def test_step_counter_increments(self, tiny):
        state = tiny.init(jnp.int32(0))
        n = len(tiny.param_specs)
        assert int(state[3 * n]) == 0
        out = tiny.train_step(*state, *_batch(tiny))
        assert int(out[3 * n]) == 1

    def test_masked_targets_ignored(self, tiny):
        state = tiny.init(jnp.int32(0))
        tokens, targets = _batch(tiny)
        all_masked = jnp.full_like(targets, -1)
        out = tiny.eval_loss(*state[: len(tiny.param_specs)], tokens, all_masked)
        assert float(out[0]) == 0.0

    def test_flash_and_ref_agree_on_loss(self, tiny, tiny_flash):
        state = tiny.init(jnp.int32(0))
        n = len(tiny.param_specs)
        tokens, targets = _batch(tiny)
        l_ref = tiny.eval_loss(*state[:n], tokens, targets)[0]
        l_flash = tiny_flash.eval_loss(*state[:n], tokens, targets)[0]
        np.testing.assert_allclose(float(l_ref), float(l_flash), atol=1e-3, rtol=1e-4)

    def test_moe_train_step_descends(self):
        bundle = ModelBundle("tiny", moe=True, kernel="ref")
        state = bundle.init(jnp.int32(0))
        tokens, targets = _batch(bundle)
        step = jax.jit(bundle.train_step)
        first = last = None
        for _ in range(6):
            out = step(*state, tokens, targets)
            state, loss = out[:-1], out[-1]
            first = first if first is not None else float(loss)
            last = float(loss)
        assert last < first

    def test_grad_clip_keeps_params_finite(self, tiny):
        state = tiny.init(jnp.int32(0))
        tokens, targets = _batch(tiny)
        # adversarial: repeat many steps on one batch at high LR
        bundle = ModelBundle("tiny", kernel="ref", learning_rate=0.05)
        step = jax.jit(bundle.train_step)
        for _ in range(10):
            out = step(*state, tokens, targets)
            state = out[:-1]
        assert all(bool(jnp.all(jnp.isfinite(s))) for s in state[: len(tiny.param_specs)])


class TestConfigVariants:
    def test_moe_swap_changes_only_ffn(self):
        dense = build_model_config("tiny")
        moe = build_model_config("tiny", moe=True)
        assert dense.decoder.layer.feed_forward.klass.__name__ == "FeedForward"
        assert moe.decoder.layer.feed_forward.klass is MoE
        # attention untouched (strict encapsulation)
        assert (
            dense.decoder.layer.self_attention.klass
            is moe.decoder.layer.self_attention.klass
        )

    def test_rope_toggle(self):
        on = build_model_config("tiny", rope=True)
        off = build_model_config("tiny", rope=False)
        assert on.decoder.layer.self_attention.pos_emb.klass is RotaryEmbedding
        assert off.decoder.layer.self_attention.pos_emb.klass is NoPositionalEmbedding

    def test_rope_improves_over_nope_is_not_required_but_both_train(self):
        for rope in (True, False):
            bundle = ModelBundle("tiny", rope=rope, kernel="ref")
            state = bundle.init(jnp.int32(0))
            out = bundle.train_step(*state, *_batch(bundle))
            assert np.isfinite(float(out[-1]))


class TestServing:
    @pytest.fixture(scope="class")
    def setup(self):
        bundle = ModelBundle("tiny", kernel="ref")
        state = bundle.init(jnp.int32(7))
        params = state[: len(bundle.param_specs)]
        return bundle, params

    def test_prefill_decode_matches_full_forward_greedy(self, setup):
        """Greedy generation via prefill+decode == argmax over the full
        forward pass run incrementally (the §6 unification check)."""
        bundle, params = setup
        b, s = 2, 10
        tokens = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, 256, jnp.int32)
        plen = jnp.array([6, 9], jnp.int32)
        nt, kc, vc = bundle.prefill(*params, tokens, plen)
        # Reference: full forward, take argmax at plen-1
        n = len(bundle.param_specs)
        tree = jax.tree_util.tree_unflatten(bundle.treedef, params)
        logits = bundle.model._children["decoder"](tree["decoder"], tokens)
        for i in range(b):
            expected = int(jnp.argmax(logits[i, int(plen[i]) - 1]))
            assert int(nt[i]) == expected

    def test_decode_continues_consistently(self, setup):
        """decode() after prefill == running the full forward over the
        extended sequence (token-level equivalence, greedy)."""
        bundle, params = setup
        b, s = 1, 8
        tokens = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, 256, jnp.int32)
        plen = jnp.array([s], jnp.int32)
        nt, kc, vc = bundle.prefill(*params, tokens, plen)
        pos = plen.astype(jnp.int32)
        generated = [int(nt[0])]
        for _ in range(4):
            nt, kc, vc = bundle.decode(*params, kc, vc, pos, nt)
            generated.append(int(nt[0]))
            pos = pos + 1
        # reference: grow the sequence with the generated tokens
        n = len(bundle.param_specs)
        tree = jax.tree_util.tree_unflatten(bundle.treedef, params)
        seq = list(map(int, tokens[0]))
        for g_prev in generated[:-1]:
            seq_arr = jnp.array([seq + [g_prev]], jnp.int32)
            logits = bundle.model._children["decoder"](tree["decoder"], seq_arr)
            seq.append(g_prev)
        # last generated token from reference
        logits = bundle.model._children["decoder"](tree["decoder"], jnp.array([seq], jnp.int32))
        expected_last = int(jnp.argmax(logits[0, -1]))
        assert generated[-1] == expected_last

    def test_insert_slot(self, setup):
        bundle, params = setup
        hp = bundle.hp
        L, H, dh, S = hp["num_layers"], hp["num_heads"], hp["head_dim"], hp["max_seq_len"]
        full_k = jnp.zeros((L, 4, S, H, dh))
        full_v = jnp.zeros((L, 4, S, H, dh))
        one_k = jnp.ones((L, 1, S, H, dh))
        one_v = jnp.ones((L, 1, S, H, dh)) * 2
        fk, fv = bundle.insert_slot(full_k, full_v, one_k, one_v, jnp.int32(2))
        assert float(fk[:, 2].min()) == 1.0
        assert float(fv[:, 2].max()) == 2.0
        assert float(fk[:, 0].max()) == 0.0
        assert float(fk[:, 3].max()) == 0.0

    def test_decode_rows_independent(self, setup):
        """Continuous batching soundness: a row's decode output must not
        depend on other rows in the batch."""
        bundle, params = setup
        b, s = 2, 8
        tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, 256, jnp.int32)
        plen = jnp.array([8, 4], jnp.int32)
        nt, kc, vc = bundle.prefill(*params, tokens, plen)
        nt2, _, _ = bundle.decode(*params, kc, vc, plen, nt)
        # same row 0 alone (batch of 1)
        nt_solo, kc1, vc1 = bundle.prefill(*params, tokens[:1], plen[:1])
        nt2_solo, _, _ = bundle.decode(*params, kc1, vc1, plen[:1], nt_solo)
        assert int(nt[0]) == int(nt_solo[0])
        assert int(nt2[0]) == int(nt2_solo[0])


class TestParamAccounting:
    def test_param_counts_match_presets(self):
        from compile.configs import PRESETS, param_count

        for preset in ("tiny", "small"):
            bundle = ModelBundle(preset, kernel="ref")
            approx = param_count(PRESETS[preset])
            actual = bundle.param_count()
            # tied embedding: approx counts it twice, allow slack
            assert abs(actual - approx) / approx < 0.5, (preset, actual, approx)

    def test_base100m_is_about_100m(self):
        from compile.configs import PRESETS, param_count

        approx = param_count(PRESETS["base100m"])
        assert 80e6 < approx < 130e6
