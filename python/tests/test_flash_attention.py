"""L1 correctness: Pallas flash-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes per the repro harness contract; tolerances
are per-dtype (f32 tight, bf16 loose).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import (
    flash_attention,
    flash_attention_with_lse,
)
from compile.kernels import ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _make_qkv(seed, b, h, q_len, kv_len, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        _rand(k1, (b, h, q_len, d), dtype),
        _rand(k2, (b, h, kv_len, d), dtype),
        _rand(k3, (b, h, kv_len, d), dtype),
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    q_len=st.integers(1, 96),
    extra_kv=st.integers(0, 64),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_f32(b, h, q_len, extra_kv, d, causal, seed):
    kv_len = q_len + extra_kv
    q, k, v = _make_qkv(seed, b, h, q_len, kv_len, d, jnp.float32)
    out = flash_attention(q, k, v, causal)
    expected = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expected, atol=TOL[jnp.float32], rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    q_len=st.integers(4, 64),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_bf16(q_len, d, seed):
    q, k, v = _make_qkv(seed, 2, 2, q_len, q_len, d, jnp.bfloat16)
    out = flash_attention(q, k, v, True)
    expected = ref.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    np.testing.assert_allclose(
        out.astype(jnp.float32), expected, atol=TOL[jnp.bfloat16], rtol=5e-2
    )
    assert out.dtype == jnp.bfloat16


@pytest.mark.parametrize("block", [(16, 16), (32, 64), (128, 128)])
def test_block_size_invariance(block):
    """The result must not depend on the tiling."""
    q, k, v = _make_qkv(7, 2, 2, 80, 80, 32, jnp.float32)
    bq, bk = block
    out = flash_attention(q, k, v, True, None, bq, bk)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=1e-4)


def test_lse_matches_ref():
    q, k, v = _make_qkv(3, 2, 3, 48, 48, 16, jnp.float32)
    out, lse = flash_attention_with_lse(q, k, v, causal=True)
    ref_out, ref_lse = ref.attention_ref_lse(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref_out, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(lse, ref_lse, atol=1e-4, rtol=1e-4)


def test_cross_attention_alignment():
    """q_len < kv_len: causal mask must be end-aligned (decode semantics)."""
    q, k, v = _make_qkv(11, 1, 2, 8, 40, 16, jnp.float32)
    out = flash_attention(q, k, v, True)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    q_len=st.integers(2, 40),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_gradients_match_ref(q_len, d, causal, seed):
    """FA-2 backward kernels vs autodiff through the reference."""
    q, k, v = _make_qkv(seed, 1, 2, q_len, q_len, d, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 2, q_len, d))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal) * w)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=causal) * w)

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gf, gr, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(gf, gr, atol=5e-4, rtol=1e-3, err_msg=f"d{name}")


def test_grad_under_jit():
    """The custom VJP must survive jit + composition with other ops."""
    q, k, v = _make_qkv(5, 1, 1, 16, 16, 8, jnp.float32)

    @jax.jit
    def f(q, k, v):
        return jnp.mean(flash_attention(q, k, v, True) ** 2)

    g = jax.grad(f)(q, k, v)
    assert g.shape == q.shape
    assert bool(jnp.all(jnp.isfinite(g)))


def test_numerical_stability_large_logits():
    """Online softmax must not overflow with large-magnitude scores."""
    q, k, v = _make_qkv(9, 1, 1, 32, 32, 16, jnp.float32)
    q = q * 100.0
    out = flash_attention(q, k, v, True)
    expected = ref.attention_ref(q, k, v, causal=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, expected, atol=1e-4, rtol=1e-3)


def test_single_token_decode_shape():
    """q_len=1 against a long KV — the decode hot path."""
    q, k, v = _make_qkv(13, 4, 2, 1, 129, 32, jnp.float32)
    out = flash_attention(q, k, v, True)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=1e-4)
