"""Fused RMSNorm Pallas kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rmsnorm import hbm_traffic_model, rmsnorm


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 100),
    dim=st.sampled_from([8, 32, 64, 256]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_f32(rows, dim, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (rows, dim), jnp.float32) * 3.0
    w = jax.random.normal(k2, (dim,), jnp.float32)
    np.testing.assert_allclose(
        rmsnorm(x, w), ref.rmsnorm_ref(x, w), atol=1e-5, rtol=1e-5
    )


def test_batched_shape():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 16))
    w = jnp.ones((16,))
    out = rmsnorm(x, w)
    assert out.shape == (2, 7, 16)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, w), atol=1e-5)


def test_bf16_dtype_preserved():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32)).astype(jnp.bfloat16)
    w = jnp.ones((32,), jnp.float32)
    out = rmsnorm(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32),
        ref.rmsnorm_ref(x, w).astype(jnp.float32),
        atol=2e-2,
        rtol=2e-2,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_gradients_match_ref(seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (6, 24))
    w = jax.random.normal(k2, (24,)) + 1.0
    co = jax.random.normal(k3, (6, 24))

    def f_kernel(x, w):
        return jnp.sum(rmsnorm(x, w) * co)

    def f_ref(x, w):
        return jnp.sum(ref.rmsnorm_ref(x, w) * co)

    gx1, gw1 = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx1, gx2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(gw1, gw2, atol=1e-4, rtol=1e-3)


def test_large_magnitude_stable():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) * 1e4
    w = jnp.ones((64,))
    out = rmsnorm(x, w)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_fusion_traffic_model():
    # §7.2: the unfused path moves ~2.5x the bytes of the fused one
    fused = hbm_traffic_model(4096, 4096, 2.0, fused=True)
    unfused = hbm_traffic_model(4096, 4096, 2.0, fused=False)
    assert 2.0 < unfused / fused < 6.0
