"""AOT pipeline tests: manifest well-formedness and HLO text validity."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile.aot import ManifestWriter, state_specs, to_hlo_text
from compile.model import ModelBundle


@pytest.fixture(scope="module")
def out(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("artifacts"))
    w = ManifestWriter(d)
    bundle = ModelBundle("tiny", kernel="ref")
    st = state_specs(bundle)
    names = (
        [f"param/{n}" for n, _, _ in bundle.param_specs]
        + [f"opt_m/{n}" for n, _, _ in bundle.param_specs]
        + [f"opt_v/{n}" for n, _, _ in bundle.param_specs]
        + ["step"]
    )
    tok = jax.ShapeDtypeStruct((2, 16), jnp.int32)
    w.lower("t_init", "init", bundle.init, [jax.ShapeDtypeStruct((), jnp.int32)],
            bundle=bundle, input_names=["seed"], output_specs=names)
    w.lower("t_step", "train_step", bundle.train_step, st + [tok, tok],
            bundle=bundle, input_names=names + ["tokens", "targets"],
            output_specs=names + ["loss"])
    w.finish()
    return d, bundle


def test_hlo_text_is_parseable_hlo(out):
    d, _ = out
    text = open(os.path.join(d, "t_step.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_structure(out):
    d, bundle = out
    text = open(os.path.join(d, "manifest.txt")).read()
    blocks = [b for b in text.strip().split("\n\n") if b]
    assert len(blocks) == 2
    for block in blocks:
        lines = block.splitlines()
        assert lines[0].startswith("artifact ")
        assert lines[-1] == "end"
        kinds = [l for l in lines if l.startswith("kind ")]
        assert len(kinds) == 1


def test_manifest_io_counts(out):
    d, bundle = out
    text = open(os.path.join(d, "manifest.txt")).read()
    step_block = [b for b in text.split("\n\n") if b.startswith("artifact t_step")][0]
    n = len(bundle.param_specs)
    inputs = [l for l in step_block.splitlines() if l.startswith("input ")]
    outputs = [l for l in step_block.splitlines() if l.startswith("output ")]
    assert len(inputs) == 3 * n + 1 + 2  # state + step + tokens/targets
    assert len(outputs) == 3 * n + 1 + 1  # state + step + loss


def test_state_roundtrip_order_is_deterministic():
    b1 = ModelBundle("tiny", kernel="ref")
    b2 = ModelBundle("tiny", kernel="ref")
    assert b1.param_specs == b2.param_specs


def test_init_state_shapes_match_specs():
    bundle = ModelBundle("tiny", kernel="ref")
    state = bundle.init(jnp.int32(0))
    n = len(bundle.param_specs)
    assert len(state) == 3 * n + 1
    for (name, shape, dtype), leaf in zip(bundle.param_specs, state[:n]):
        assert tuple(leaf.shape) == shape, name


def test_hlo_text_executes_via_xla_client(out):
    """Round-trip the HLO text through the embedded XLA client — the same
    parse the Rust runtime performs."""
    d, bundle = out
    from jax._src.lib import xla_client as xc

    text = open(os.path.join(d, "t_init.hlo.txt")).read()
    # the text must at least be structurally valid HLO; executing it happens
    # in rust (cargo test runtime_roundtrip). Here: verify non-trivial size
    # and entry computation signature mentions the seed input.
    assert "s32[]" in text
