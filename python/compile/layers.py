"""Layer-2 layer library: a modular transformer in AXLearn's style.

Every layer:
  * declares a ``Config`` via ``default_config()`` (hierarchical, child
    configs encapsulated — §4.1 of the paper);
  * is instantiated from its config, with the parent propagating shared
    dims (``input_dim``) into partially-specified children;
  * exposes pure functions ``init(key) -> params`` and
    ``__call__(params, ...) -> out`` so the whole model stays functional
    and can be lowered by ``jax.jit``.

The FFN <-> MoE swap of Figure 1 works verbatim here: ``FeedForward`` and
``MoE`` share the input/output interface, so ``replace_config`` (see
``configs.py``) drops MoE into any model without touching other modules.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .configs import Config
from .kernels.flash_attention import flash_attention
from .kernels import ref as kref

Params = dict


class BaseLayer:
    """Root of the layer library.  Children are added with ``_add_child``
    which mirrors AXLearn's module-tree construction (§3)."""

    @classmethod
    def default_config(cls) -> Config:
        raise NotImplementedError

    def __init__(self, cfg: Config):
        self.cfg = cfg
        self._children: dict[str, "BaseLayer"] = {}

    def _add_child(self, name: str, child_cfg: Config) -> "BaseLayer":
        child = child_cfg.instantiate()
        self._children[name] = child
        return child

    def init(self, key: jax.Array) -> Params:
        """Initialize parameters for this layer and its children."""
        params: Params = {}
        for name, child in self._children.items():
            key, sub = jax.random.split(key)
            params[name] = child.init(sub)
        return params


def _topk_by_argmax(x: jnp.ndarray, k: int):
    """Top-k over the last dim via k argmax passes (parser-safe lowering).

    Equivalent to ``jax.lax.top_k`` up to tie-breaking.  x: [T, E].
    """
    t = x.shape[0]
    rows = jnp.arange(t)
    work = x
    vals, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(work, axis=-1)
        val = jnp.take_along_axis(work, idx[:, None], axis=-1)[:, 0]
        vals.append(val)
        idxs.append(idx)
        work = work.at[rows, idx].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _dense_init(key, shape, fan_in):
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


class Linear(BaseLayer):
    """Dense projection.  ``param_partition_spec`` mirrors the paper's
    sharding-by-config: it is carried into the artifact manifest so the Rust
    composer can reason about parameter placement."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(cls, input_dim=None, output_dim=None, use_bias=False,
                      param_partition_spec=("fsdp", "model"))

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        kw, kb = jax.random.split(key)
        params = {"weight": _dense_init(kw, (cfg.input_dim, cfg.output_dim), cfg.input_dim)}
        if cfg.use_bias:
            params["bias"] = jnp.zeros((cfg.output_dim,), jnp.float32)
        return params

    def __call__(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        out = x @ params["weight"]
        if self.cfg.use_bias:
            out = out + params["bias"]
        return out


class Embedding(BaseLayer):
    @classmethod
    def default_config(cls) -> Config:
        return Config(cls, num_embeddings=None, dim=None)

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        return {"weight": jax.random.normal(key, (cfg.num_embeddings, cfg.dim), jnp.float32) * 0.02}

    def __call__(self, params: Params, ids: jnp.ndarray) -> jnp.ndarray:
        return params["weight"][ids]

    def attend(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        """Tied-weight logits (used when the LM head is tied)."""
        return x @ params["weight"].T


class RMSNorm(BaseLayer):
    @classmethod
    def default_config(cls) -> Config:
        return Config(cls, input_dim=None, eps=1e-6)

    def init(self, key: jax.Array) -> Params:
        return {"scale": jnp.ones((self.cfg.input_dim,), jnp.float32)}

    def __call__(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        return kref.rmsnorm_ref(x, params["scale"], self.cfg.eps)


# -- positional embeddings ---------------------------------------------------
class NoPositionalEmbedding(BaseLayer):
    """Identity rotary slot — the 'nope' variant."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(cls)

    def apply_rotary(self, q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray):
        return q, k


class RotaryEmbedding(BaseLayer):
    """RoPE, strictly encapsulated: attention only knows the
    ``apply_rotary`` interface, never RoPE's own hyper-parameters.  This is
    the encapsulation boundary whose absence costs other systems O(NM) LoC
    (paper §7.1)."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(cls, theta=10000.0)

    def apply_rotary(self, q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray):
        """q, k: [batch, seq, heads, head_dim]; positions: [batch, seq]."""

        def rot(x):
            head_dim = x.shape[-1]
            half = head_dim // 2
            freqs = 1.0 / (self.cfg.theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
            angles = positions.astype(jnp.float32)[..., None] * freqs  # [b, s, half]
            cos = jnp.cos(angles)[:, :, None, :]
            sin = jnp.sin(angles)[:, :, None, :]
            x1, x2 = x[..., :half], x[..., half:]
            return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)

        return rot(q), rot(k)


# -- attention ---------------------------------------------------------------
class AttentionLayer(BaseLayer):
    """Multi-head attention with a pluggable kernel and pluggable positional
    embedding.  KV-cache handling is encapsulated here (paper §6): the
    prefill/decode entry points below are what the serving graphs use, and
    swapping cache layout or kernel is a config change."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(
            cls,
            input_dim=None,
            num_heads=None,
            head_dim=None,
            pos_emb=RotaryEmbedding.default_config(),
            kernel="flash",  # "flash" (Pallas) | "ref" (pure jnp)
            qkv_proj=Linear.default_config(),
            out_proj=Linear.default_config(),
        )

    def __init__(self, cfg: Config):
        super().__init__(cfg)
        inner = cfg.num_heads * cfg.head_dim
        self._add_child("q_proj", cfg.qkv_proj.clone().set(input_dim=cfg.input_dim, output_dim=inner))
        self._add_child("k_proj", cfg.qkv_proj.clone().set(input_dim=cfg.input_dim, output_dim=inner))
        self._add_child("v_proj", cfg.qkv_proj.clone().set(input_dim=cfg.input_dim, output_dim=inner))
        self._add_child("o_proj", cfg.out_proj.clone().set(input_dim=inner, output_dim=cfg.input_dim))
        self._add_child("pos_emb", cfg.pos_emb)

    def _qkv(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray):
        cfg = self.cfg
        b, s, _ = x.shape
        shape = (b, s, cfg.num_heads, cfg.head_dim)
        q = self._children["q_proj"](params["q_proj"], x).reshape(shape)
        k = self._children["k_proj"](params["k_proj"], x).reshape(shape)
        v = self._children["v_proj"](params["v_proj"], x).reshape(shape)
        q, k = self._children["pos_emb"].apply_rotary(q, k, positions)
        return q, k, v

    def __call__(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
        """Full causal self-attention (training / prefill-style)."""
        cfg = self.cfg
        b, s, _ = x.shape
        q, k, v = self._qkv(params, x, positions)
        qh = q.transpose(0, 2, 1, 3)  # [b, h, s, d]
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        if cfg.kernel == "flash":
            ctx = flash_attention(qh, kh, vh, True)
        else:
            ctx = kref.attention_ref(qh, kh, vh, causal=True)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
        return self._children["o_proj"](params["o_proj"], ctx)

    def prefill(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray):
        """Causal attention that also returns the KV cache slabs.

        Returns ``(out, k_cache, v_cache)`` with caches shaped
        [batch, seq, heads, head_dim] (post-RoPE keys, ready for decode).
        """
        cfg = self.cfg
        b, s, _ = x.shape
        q, k, v = self._qkv(params, x, positions)
        qh, kh, vh = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        if cfg.kernel == "flash":
            ctx = flash_attention(qh, kh, vh, True)
        else:
            ctx = kref.attention_ref(qh, kh, vh, causal=True)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
        return self._children["o_proj"](params["o_proj"], ctx), k, v

    def decode_step(
        self,
        params: Params,
        x: jnp.ndarray,           # [batch, 1, dim] current-token activations
        pos: jnp.ndarray,         # [batch] current position of each row
        k_cache: jnp.ndarray,     # [batch, max_seq, heads, head_dim]
        v_cache: jnp.ndarray,
    ):
        """Single-token decode with per-row positions (continuous batching:
        rows of the same batch may be at different depths)."""
        cfg = self.cfg
        b = x.shape[0]
        q, k, v = self._qkv(params, x, pos[:, None])  # each [b, 1, heads, head_dim]
        # write this step's k/v into the cache at each row's position
        idx = pos[:, None, None, None]
        onehot = jnp.arange(k_cache.shape[1])[None, :, None, None] == idx  # [b, S, 1, 1]
        k_cache = jnp.where(onehot, k, k_cache)
        v_cache = jnp.where(onehot, v, v_cache)
        # attend over positions <= pos (per row)
        scale = 1.0 / math.sqrt(cfg.head_dim)
        logits = jnp.einsum("bhd,bshd->bhs", q[:, 0], k_cache) * scale
        k_pos = jnp.arange(k_cache.shape[1])[None, None, :]
        mask = k_pos <= pos[:, None, None]
        logits = jnp.where(mask, logits, kref.NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhs,bshd->bhd", probs, v_cache)
        ctx = ctx.reshape(b, 1, cfg.num_heads * cfg.head_dim)
        out = self._children["o_proj"](params["o_proj"], ctx)
        return out, k_cache, v_cache


# -- feed-forward variants ----------------------------------------------------
class FeedForward(BaseLayer):
    """SwiGLU FFN (paper §4.1 example)."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(
            cls,
            input_dim=None,
            hidden_dim=None,
            linear=Linear.default_config(),
        )

    def __init__(self, cfg: Config):
        super().__init__(cfg)
        self._add_child("gate", cfg.linear.clone().set(input_dim=cfg.input_dim, output_dim=cfg.hidden_dim))
        self._add_child("up", cfg.linear.clone().set(input_dim=cfg.input_dim, output_dim=cfg.hidden_dim))
        self._add_child("down", cfg.linear.clone().set(input_dim=cfg.hidden_dim, output_dim=cfg.input_dim))

    def __call__(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        g = jax.nn.silu(self._children["gate"](params["gate"], x))
        u = self._children["up"](params["up"], x)
        return self._children["down"](params["down"], g * u)


class MoE(BaseLayer):
    """Top-k gated Mixture-of-Experts, interface-compatible with
    ``FeedForward`` — the drop-in replacement of Figure 1.

    Gating: softmax router, top-k selection with renormalized weights, and a
    Switch-style load-balance auxiliary loss.  The aux loss is *collected
    through the InvocationContext analogue* (an output side-channel), not
    returned through the call signature, so no ancestor module changes when
    MoE is swapped in (the paper's core claim).
    """

    # Side-channel for auxiliary losses (mirrors InvocationContext output
    # collection; the jax graph stays functional because the trainer drains
    # it within a single trace).
    _aux_losses: list = []

    @classmethod
    def default_config(cls) -> Config:
        return Config(
            cls,
            input_dim=None,
            hidden_dim=None,
            num_experts=8,
            top_k=2,
            aux_loss_weight=0.01,
            linear=Linear.default_config(),
        )

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 4)
        e, d, h = cfg.num_experts, cfg.input_dim, cfg.hidden_dim
        return {
            "router": _dense_init(keys[0], (d, e), d),
            "gate": _dense_init(keys[1], (e, d, h), d),
            "up": _dense_init(keys[2], (e, d, h), d),
            "down": _dense_init(keys[3], (e, h, d), h),
        }

    def __call__(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        b, s, d = x.shape
        tokens = x.reshape(b * s, d)
        router_logits = tokens @ params["router"]                  # [T, E]
        router_probs = jax.nn.softmax(router_logits, axis=-1)
        # iterative-argmax top-k: jax.lax.top_k lowers to an HLO `topk`
        # instruction that xla_extension 0.5.1's text parser rejects
        top_w, top_idx = _topk_by_argmax(router_probs, cfg.top_k)  # [T, k]
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        # Sparse combine weights as a dense [T, E] matrix (exact top-k MoE
        # semantics; each expert computed densely — fine at repro scale, and
        # the expert-parallel cost model prices the sparse dispatch).
        combine = jnp.zeros_like(router_probs).at[
            jnp.arange(tokens.shape[0])[:, None], top_idx
        ].set(top_w)
        # expert FFNs: [E, T, h]
        g = jax.nn.silu(jnp.einsum("td,edh->eth", tokens, params["gate"]))
        u = jnp.einsum("td,edh->eth", tokens, params["up"])
        expert_out = jnp.einsum("eth,ehd->etd", g * u, params["down"])
        out = jnp.einsum("te,etd->td", combine, expert_out)
        # Switch-transformer load balance loss: E * sum_e f_e * P_e
        f = (combine > 0).astype(jnp.float32).mean(axis=0)         # fraction routed
        p = router_probs.mean(axis=0)
        aux = cfg.num_experts * jnp.sum(f * p) * cfg.aux_loss_weight
        MoE._aux_losses.append(aux)
        return out.reshape(b, s, d)

    @classmethod
    def drain_aux_losses(cls) -> jnp.ndarray:
        total = sum(cls._aux_losses) if cls._aux_losses else jnp.array(0.0)
        cls._aux_losses = []
        return total


# -- transformer --------------------------------------------------------------
class TransformerLayer(BaseLayer):
    """Pre-norm transformer block.  Children (attention, FFN) are
    encapsulated configs — §4.1's running example."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(
            cls,
            input_dim=None,
            self_attention=AttentionLayer.default_config(),
            feed_forward=FeedForward.default_config(),
            norm=RMSNorm.default_config(),
        )

    def __init__(self, cfg: Config):
        super().__init__(cfg)
        cfg.self_attention.set(input_dim=cfg.input_dim)
        # hidden_dim may be a callable of input_dim (scaled_hidden_dim style)
        ff = cfg.feed_forward
        ff.set(input_dim=cfg.input_dim)
        if callable(ff.hidden_dim):
            ff.set(hidden_dim=ff.hidden_dim(cfg.input_dim))
        self._add_child("attn_norm", cfg.norm.clone().set(input_dim=cfg.input_dim))
        self._add_child("ffn_norm", cfg.norm.clone().set(input_dim=cfg.input_dim))
        self._add_child("self_attention", cfg.self_attention)
        self._add_child("feed_forward", ff)

    def __call__(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
        h = self._children["attn_norm"](params["attn_norm"], x)
        x = x + self._children["self_attention"](params["self_attention"], h, positions)
        h = self._children["ffn_norm"](params["ffn_norm"], x)
        x = x + self._children["feed_forward"](params["feed_forward"], h)
        return x

    def prefill(self, params: Params, x, positions):
        h = self._children["attn_norm"](params["attn_norm"], x)
        attn_out, k, v = self._children["self_attention"].prefill(params["self_attention"], h, positions)
        x = x + attn_out
        h = self._children["ffn_norm"](params["ffn_norm"], x)
        x = x + self._children["feed_forward"](params["feed_forward"], h)
        return x, k, v

    def decode_step(self, params: Params, x, pos, k_cache, v_cache):
        h = self._children["attn_norm"](params["attn_norm"], x)
        attn_out, k_cache, v_cache = self._children["self_attention"].decode_step(
            params["self_attention"], h, pos, k_cache, v_cache
        )
        x = x + attn_out
        h = self._children["ffn_norm"](params["ffn_norm"], x)
        x = x + self._children["feed_forward"](params["feed_forward"], h)
        return x, k_cache, v_cache


class Decoder(BaseLayer):
    """Embedding + N transformer layers + final norm + (tied) LM head."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(
            cls,
            vocab_size=None,
            model_dim=None,
            num_layers=None,
            emb=Embedding.default_config(),
            layer=TransformerLayer.default_config(),
            output_norm=RMSNorm.default_config(),
            tied_lm_head=True,
        )

    def __init__(self, cfg: Config):
        super().__init__(cfg)
        self._add_child("emb", cfg.emb.clone().set(num_embeddings=cfg.vocab_size, dim=cfg.model_dim))
        self.layers = []
        for i in range(cfg.num_layers):
            layer = self._add_child(f"layer{i}", cfg.layer.clone().set(input_dim=cfg.model_dim))
            self.layers.append(layer)
        self._add_child("output_norm", cfg.output_norm.clone().set(input_dim=cfg.model_dim))
        if not cfg.tied_lm_head:
            self._add_child(
                "lm_head", Linear.default_config().set(input_dim=cfg.model_dim, output_dim=cfg.vocab_size)
            )

    def _logits(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        x = self._children["output_norm"](params["output_norm"], x)
        if self.cfg.tied_lm_head:
            return self._children["emb"].attend(params["emb"], x)
        return self._children["lm_head"](params["lm_head"], x)

    def __call__(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens: [batch, seq] -> logits [batch, seq, vocab]."""
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._children["emb"](params["emb"], tokens)
        for i, layer in enumerate(self.layers):
            x = layer(params[f"layer{i}"], x, positions)
        return self._logits(params, x)

    def prefill(self, params: Params, tokens: jnp.ndarray):
        """Returns (logits, k_caches, v_caches) with caches
        [layers, batch, seq, heads, head_dim]."""
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._children["emb"](params["emb"], tokens)
        ks, vs = [], []
        for i, layer in enumerate(self.layers):
            x, k, v = layer.prefill(params[f"layer{i}"], x, positions)
            ks.append(k)
            vs.append(v)
        return self._logits(params, x), jnp.stack(ks), jnp.stack(vs)

    def decode_step(self, params: Params, token: jnp.ndarray, pos: jnp.ndarray, k_caches, v_caches):
        """token: [batch] -> (logits [batch, vocab], new caches)."""
        x = self._children["emb"](params["emb"], token[:, None])
        new_k, new_v = [], []
        for i, layer in enumerate(self.layers):
            x, kc, vc = layer.decode_step(params[f"layer{i}"], x, pos, k_caches[i], v_caches[i])
            new_k.append(kc)
            new_v.append(vc)
        logits = self._logits(params, x)[:, 0]
        return logits, jnp.stack(new_k), jnp.stack(new_v)


class CausalLM(BaseLayer):
    """Next-token-prediction wrapper: cross-entropy + MoE aux losses."""

    @classmethod
    def default_config(cls) -> Config:
        return Config(cls, decoder=Decoder.default_config(), z_loss_weight=0.0)

    def __init__(self, cfg: Config):
        super().__init__(cfg)
        self._add_child("decoder", cfg.decoder)

    def loss(self, params: Params, tokens: jnp.ndarray, targets: jnp.ndarray):
        """tokens, targets: [batch, seq]; target < 0 positions are masked."""
        logits = self._children["decoder"](params["decoder"], tokens)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.maximum(targets, 0)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = (targets >= 0).astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        aux = MoE.drain_aux_losses()
        z_loss = self.cfg.z_loss_weight * ((logz * mask) ** 2).sum() / denom
        return ce + aux + z_loss, {"ce": ce, "aux": aux}
