"""Hierarchical, strictly-encapsulated configs (python mirror of AXLearn §4.1).

The Rust coordinator owns the *production* config system
(``rust/src/config``); this module is its build-time mirror so that the
Layer-2 model definition follows the same composition discipline the paper
describes: every layer has a ``Config``, child configs are encapsulated,
partially-specified configs propagate parent dims at instantiation time, and
arbitrary tree rewrites (``replace_config``) implement the paper's 10-line
MoE/RoPE swaps.
"""

from __future__ import annotations

import copy
from typing import Any, Callable, Optional


class Config:
    """A node in the config tree.

    A ``Config`` pairs the class it instantiates (``klass``) with a dict of
    fields.  Field values may themselves be ``Config`` objects, forming the
    hierarchical tree of AXLearn §4.1.  Fields may be left ``None``
    (partially specified) and filled in by the parent at instantiation time
    — e.g. ``TransformerLayer`` propagates ``input_dim`` into its children.
    """

    def __init__(self, klass: type, **fields: Any):
        self.klass = klass
        self._fields: dict[str, Any] = dict(fields)

    # -- field access ------------------------------------------------------
    def __getattr__(self, name: str) -> Any:
        if name.startswith("_") or name == "klass":
            raise AttributeError(name)
        try:
            return self._fields[name]
        except KeyError:
            raise AttributeError(f"{self.klass.__name__}.Config has no field {name!r}")

    def __setattr__(self, name: str, value: Any) -> None:
        if name in ("klass", "_fields"):
            object.__setattr__(self, name, value)
        else:
            self._fields[name] = value

    def set(self, **kwargs: Any) -> "Config":
        """Set fields, returning self (enables the fluent style of §4.1)."""
        for k, v in kwargs.items():
            if k not in self._fields:
                raise KeyError(f"{self.klass.__name__}.Config has no field {k!r}")
            self._fields[k] = v
        return self

    def fields(self) -> dict[str, Any]:
        return dict(self._fields)

    def clone(self) -> "Config":
        return copy.deepcopy(self)

    # -- instantiation -----------------------------------------------------
    def instantiate(self) -> Any:
        """Build the layer.  Validation of required fields happens in the
        layer's ``__init__`` so errors carry layer context."""
        return self.klass(self.clone())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        inner = ", ".join(f"{k}={v!r}" for k, v in self._fields.items())
        return f"{self.klass.__name__}.Config({inner})"


def config_for_function(fn: Callable, **defaults: Any) -> Config:
    """AXLearn's ``config_for_function``: wrap an arbitrary callable into a
    config whose instantiation returns ``functools.partial``-like closure."""

    class _FnLayer:
        def __init__(self, cfg: Config):
            self._fn = fn
            self._kwargs = {k: v for k, v in cfg.fields().items() if v is not None}

        def __call__(self, *args, **kw):
            merged = dict(self._kwargs)
            merged.update(kw)
            return self._fn(*args, **merged)

    _FnLayer.__name__ = f"FnLayer[{fn.__name__}]"
    return Config(_FnLayer, **defaults)


def replace_config(
    cfg: Config,
    target: type,
    new_cfg_factory: Callable[[Config], Config],
) -> Config:
    """Recursively replace any sub-config whose klass is ``target``.

    This is the python twin of the paper's §4.1 'Config traversal' snippet —
    the mechanism behind the O(1) LoC-complexity claim.  ``new_cfg_factory``
    receives the old config so the replacement can inherit propagated dims.
    """
    if isinstance(cfg, Config) and issubclass(cfg.klass, target):
        return new_cfg_factory(cfg)
    if isinstance(cfg, Config):
        for name, value in cfg._fields.items():
            if isinstance(value, Config):
                cfg._fields[name] = replace_config(value, target, new_cfg_factory)
            elif isinstance(value, (list, tuple)):
                cfg._fields[name] = type(value)(
                    replace_config(v, target, new_cfg_factory) if isinstance(v, Config) else v
                    for v in value
                )
    return cfg


def visit_configs(cfg: Config, fn: Callable[[Config], None]) -> None:
    """Pre-order visit over the config tree."""
    if not isinstance(cfg, Config):
        return
    fn(cfg)
    for value in cfg._fields.values():
        if isinstance(value, Config):
            visit_configs(value, fn)
        elif isinstance(value, (list, tuple)):
            for v in value:
                if isinstance(v, Config):
                    visit_configs(v, fn)


def config_to_lines(cfg: Config, prefix: str = "") -> list[str]:
    """Serialize a config tree to the human-readable 'golden' format the
    paper commits alongside code changes (§7.3).  Matches the Rust-side
    format in ``rust/src/config/golden.rs``."""
    lines = [f"{prefix or 'root'}: {cfg.klass.__name__}"]
    for name in sorted(cfg._fields):
        value = cfg._fields[name]
        path = f"{prefix}.{name}" if prefix else name
        if isinstance(value, Config):
            lines.extend(config_to_lines(value, path))
        elif isinstance(value, (list, tuple)) and any(isinstance(v, Config) for v in value):
            for i, v in enumerate(value):
                lines.extend(config_to_lines(v, f"{path}[{i}]"))
        else:
            lines.append(f"{path} = {value!r}")
    return lines


# ---------------------------------------------------------------------------
# Model presets.  Mirrored by rust/src/composer presets; the names here are
# what `aot.py --preset` and the artifact manifest use.
# ---------------------------------------------------------------------------

PRESETS: dict[str, dict[str, Any]] = {
    # Unit-test scale: compiles in seconds, exercises every code path.
    "tiny": dict(
        vocab_size=256, model_dim=64, num_layers=2, num_heads=4, head_dim=16,
        ffn_dim=192, max_seq_len=64, num_experts=4, moe_top_k=2,
    ),
    # E2E loss-curve scale (~8.9M params): hundreds of steps on 1 CPU core.
    "small": dict(
        vocab_size=2048, model_dim=256, num_layers=4, num_heads=4, head_dim=64,
        ffn_dim=704, max_seq_len=256, num_experts=4, moe_top_k=2,
    ),
    # ~106M params: the mandated ~100M e2e smoke (a few steps on CPU).
    "base100m": dict(
        vocab_size=8192, model_dim=768, num_layers=12, num_heads=12, head_dim=64,
        ffn_dim=2048, max_seq_len=512, num_experts=8, moe_top_k=2,
    ),
    # Serving scale: small model with the KV budget sized to the Table-4/
    # Figure-5 workload (max input 256 + output 128; §Perf iteration 2 —
    # the dense KV slab round-trips through host literals every decode
    # step, so its size is the decode hot-path cost).
    "serve": dict(
        vocab_size=2048, model_dim=256, num_layers=4, num_heads=4, head_dim=64,
        ffn_dim=704, max_seq_len=384, num_experts=4, moe_top_k=2,
    ),
}


def param_count(p: dict[str, Any]) -> int:
    """Approximate dense parameter count for a preset dict."""
    d, L, f, v = p["model_dim"], p["num_layers"], p["ffn_dim"], p["vocab_size"]
    attn = 4 * d * p["num_heads"] * p["head_dim"]
    ffn = 3 * d * f  # SwiGLU: gate, up, down
    norms = 2 * d
    return v * d * 2 + L * (attn + ffn + norms) + d
