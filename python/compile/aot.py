"""AOT lowering driver: jax functions -> HLO text artifacts + manifest.

This is the single point where Python runs (``make artifacts``); afterwards
the Rust binary is self-contained.  Interchange is HLO **text** — the
image's xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit
instruction ids), while the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts and a plain-text ``manifest.txt`` (parsed by
``rust/src/runtime/manifest.rs``) land in ``artifacts/``:

  artifact <name>
  file <name>.hlo.txt
  kind init|train_step|eval_loss|prefill|decode|insert
  preset <preset>  moe <0|1>  rope <0|1>
  hyper <k>=<v> ...
  num_params <n>            # leading state leaves that are model params
  input <name> <dtype> <d0,d1,...>
  output <name> <dtype> <d0,d1,...>
  end

Usage:  python -m compile.aot --out-dir ../artifacts [--set default|all|tiny]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelBundle


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    return ",".join(str(d) for d in s) if len(s) else "scalar"


class ManifestWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def lower(self, name: str, kind: str, fn, arg_specs, *, bundle: ModelBundle | None = None,
              input_names=None, output_specs=None, extra=None):
        """Lower ``fn`` at ``arg_specs`` (ShapeDtypeStructs), write HLO text,
        record a manifest entry."""
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        lines = [f"artifact {name}", f"file {fname}", f"kind {kind}"]
        if bundle is not None:
            lines.append(f"preset {bundle.preset}")
            hyper = " ".join(f"{k}={v}" for k, v in bundle.hp.items())
            lines.append(f"hyper {hyper}")
            lines.append(f"num_params {len(bundle.param_specs)}")
        if extra:
            for k, v in extra.items():
                lines.append(f"{k} {v}")
        names = input_names or [f"arg{i}" for i in range(len(arg_specs))]
        for n, spec in zip(names, arg_specs):
            lines.append(f"input {n} {spec.dtype} {_shape_str(spec.shape)}")
        # output specs via eval_shape
        out = jax.eval_shape(fn, *arg_specs)
        flat, _ = jax.tree_util.tree_flatten(out)
        onames = output_specs or [f"out{i}" for i in range(len(flat))]
        for n, spec in zip(onames, flat):
            lines.append(f"output {n} {spec.dtype} {_shape_str(spec.shape)}")
        lines.append("end")
        self.entries.append("\n".join(lines))
        print(f"  wrote {fname} ({len(text)/1e6:.2f} MB, {len(arg_specs)} inputs, {len(flat)} outputs)")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.txt"), "w") as f:
            f.write("\n\n".join(self.entries) + "\n")
        print(f"  wrote manifest.txt ({len(self.entries)} artifacts)")


def state_specs(bundle: ModelBundle):
    """ShapeDtypeStructs for the flat train state."""
    out = jax.eval_shape(bundle.init, jax.ShapeDtypeStruct((), jnp.int32))
    return list(out)


def lower_training(w: ManifestWriter, preset: str, *, moe=False, rope=True,
                   batch: int, seq: int, with_eval=True, kernel="ref", tag=None):
    # kernel="ref" is the CPU-backend dispatch (paper §4.2: FlashAttention
    # implementations are selected per backend — cuDNN/NKI/Pallas; on the
    # CPU PJRT substrate the XLA-fused reference path IS the fast kernel,
    # while interpret-mode Pallas emulates TPU semantics ~20x slower; see
    # EXPERIMENTS.md §Perf L2).  The Pallas path stays validated by
    # python/tests AND by the `tiny_flash_eval_loss` artifact below.
    tag = tag or (preset + ("_moe" if moe else "") + ("" if rope else "_nope"))
    bundle = ModelBundle(preset, moe=moe, rope=rope, kernel=kernel)
    print(f"[{tag}] params={bundle.param_count():,}")
    st = state_specs(bundle)
    state_names = (
        [f"param/{n}" for n, _, _ in bundle.param_specs]
        + [f"opt_m/{n}" for n, _, _ in bundle.param_specs]
        + [f"opt_v/{n}" for n, _, _ in bundle.param_specs]
        + ["step"]
    )
    extra = {"batch": batch, "seq": seq, "moe": int(moe), "rope": int(rope)}
    w.lower(f"{tag}_init", "init", bundle.init, [jax.ShapeDtypeStruct((), jnp.int32)],
            bundle=bundle, input_names=["seed"], output_specs=state_names, extra=extra)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    w.lower(
        f"{tag}_train_step", "train_step", bundle.train_step, st + [tok, tok],
        bundle=bundle, input_names=state_names + ["tokens", "targets"],
        output_specs=state_names + ["loss"], extra=extra,
    )
    if with_eval:
        n = len(bundle.param_specs)
        w.lower(
            f"{tag}_eval_loss", "eval_loss", bundle.eval_loss, st[:n] + [tok, tok],
            bundle=bundle, input_names=state_names[:n] + ["tokens", "targets"],
            output_specs=["loss"], extra=extra,
        )
    return bundle


def lower_serving(w: ManifestWriter, preset="serve", *, prefill_batches=(1,),
                  prefill_lens=(128, 256), decode_batches=(1, 8)):
    bundle = ModelBundle(preset, kernel="ref")  # CPU-backend dispatch (see above)
    hp = bundle.hp
    L, H, dh, maxS = hp["num_layers"], hp["num_heads"], hp["head_dim"], hp["max_seq_len"]
    n = len(bundle.param_specs)
    pspecs = state_specs(bundle)[:n]
    pnames = [f"param/{nm}" for nm, _, _ in bundle.param_specs]
    # init (serving only needs params; reuse train init, Rust slices params)
    w.lower(f"{preset}_init", "init", bundle.init, [jax.ShapeDtypeStruct((), jnp.int32)],
            bundle=bundle, input_names=["seed"],
            output_specs=pnames + [f"_opt{i}" for i in range(2 * n)] + ["step"])
    for b in prefill_batches:
        for s in prefill_lens:
            tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
            plen = jax.ShapeDtypeStruct((b,), jnp.int32)
            w.lower(
                f"{preset}_prefill_b{b}_s{s}", "prefill", bundle.prefill, pspecs + [tok, plen],
                bundle=bundle, input_names=pnames + ["tokens", "prompt_len"],
                output_specs=["next_token", "k_cache", "v_cache"],
                extra={"batch": b, "seq": s},
            )
    for b in decode_batches:
        kc = jax.ShapeDtypeStruct((L, b, maxS, H, dh), jnp.float32)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        tokb = jax.ShapeDtypeStruct((b,), jnp.int32)
        w.lower(
            f"{preset}_decode_b{b}", "decode", bundle.decode, pspecs + [kc, kc, pos, tokb],
            bundle=bundle, input_names=pnames + ["k_cache", "v_cache", "pos", "token"],
            output_specs=["next_token", "k_cache", "v_cache"],
            extra={"batch": b, "seq": maxS},
        )
    # continuous-batching admission op
    full = jax.ShapeDtypeStruct((L, max(decode_batches), maxS, H, dh), jnp.float32)
    one = jax.ShapeDtypeStruct((L, 1, maxS, H, dh), jnp.float32)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    w.lower(
        f"{preset}_insert", "insert", ModelBundle.insert_slot, [full, full, one, one, slot],
        bundle=bundle, input_names=["full_k", "full_v", "one_k", "one_v", "slot"],
        output_specs=["full_k", "full_v"],
        extra={"batch": max(decode_batches), "seq": maxS},
    )
    return bundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="default", choices=["default", "all", "tiny"])
    args = ap.parse_args()
    w = ManifestWriter(args.out_dir)

    # Always: tiny variants (tests + quickstart run against these).
    lower_training(w, "tiny", batch=2, seq=32)
    lower_training(w, "tiny", moe=True, batch=2, seq=32, with_eval=False)
    # Pallas-kernel validation artifact: same model, flash attention in the
    # HLO.  rust/tests/runtime_smoke.rs checks its eval loss is identical
    # to the ref-kernel artifact's through the PJRT path.
    bundle_flash = ModelBundle("tiny", kernel="flash")
    n = len(bundle_flash.param_specs)
    st = state_specs(bundle_flash)
    names = [f"param/{nm}" for nm, _, _ in bundle_flash.param_specs]
    tok = jax.ShapeDtypeStruct((2, 32), jnp.int32)
    w.lower(
        "tiny_flash_eval_loss", "eval_loss", bundle_flash.eval_loss, st[:n] + [tok, tok],
        bundle=bundle_flash, input_names=names + ["tokens", "targets"],
        output_specs=["loss"], extra={"batch": 2, "seq": 32},
    )

    if args.set in ("default", "all"):
        # e2e loss-curve model (~9M params) and its MoE twin
        lower_training(w, "small", batch=4, seq=128)
        lower_training(w, "small", moe=True, batch=4, seq=128, with_eval=False)
        # serving graphs
        lower_serving(w)
        # ~100M smoke model
        lower_training(w, "base100m", batch=4, seq=256, with_eval=False)
    if args.set == "all":
        lower_training(w, "small", rope=False, batch=4, seq=128, with_eval=False)

    w.finish()


if __name__ == "__main__":
    main()
