"""Layer-2 model assembly: config -> pure jax functions for AOT lowering.

Builds the CausalLM from a preset (see ``configs.PRESETS``), plus the
training and serving entry points that ``aot.py`` lowers to HLO:

  * ``init(seed)``                         -> initial train state
  * ``train_step(state, tokens, targets)`` -> (new state, loss)   [AdamW]
  * ``prefill(params, tokens, prompt_len)``-> (next_token, k_cache, v_cache)
  * ``decode(params, caches, pos, token)`` -> (next_token, logits_max, caches)
  * ``insert_slot(full_cache, one_cache, slot)`` -> full_cache
    (continuous batching: drop a freshly-prefilled request into a live
    decode batch — paper §6)

The train state is a flat list of arrays in a deterministic order; the
flattening treedef is what the manifest (``aot.py``) records for the Rust
runtime.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import configs
from .configs import Config, replace_config
from .layers import (
    AttentionLayer,
    CausalLM,
    Decoder,
    FeedForward,
    MoE,
    NoPositionalEmbedding,
    RotaryEmbedding,
    TransformerLayer,
)


def build_model_config(
    preset: str,
    *,
    moe: bool = False,
    rope: bool = True,
    kernel: str = "flash",
) -> Config:
    """Compose the CausalLM config for a preset.

    Note how the feature knobs are *config tree rewrites*, exactly the
    paper's integration story: MoE replaces FeedForward via
    ``replace_config`` (Figure 1), RoPE on/off swaps the pos_emb child.
    """
    p = configs.PRESETS[preset]
    cfg = CausalLM.default_config()
    dec = cfg.decoder
    dec.set(vocab_size=p["vocab_size"], model_dim=p["model_dim"], num_layers=p["num_layers"])
    dec.layer.self_attention.set(num_heads=p["num_heads"], head_dim=p["head_dim"], kernel=kernel)
    dec.layer.feed_forward.set(hidden_dim=p["ffn_dim"])

    if not rope:
        replace_config(cfg, RotaryEmbedding, lambda old: NoPositionalEmbedding.default_config())
    if moe:
        # The paper's 10-line MoE swap (§4.1): any FeedForward -> MoE.
        replace_config(
            cfg,
            FeedForward,
            lambda old: MoE.default_config().set(
                input_dim=old.input_dim,
                hidden_dim=old.hidden_dim,
                num_experts=p["num_experts"],
                top_k=p["moe_top_k"],
            ),
        )
    return cfg


class ModelBundle:
    """A built model plus its train/serving functions (pre-jit)."""

    def __init__(self, preset: str, *, moe=False, rope=True, kernel="flash",
                 learning_rate=None, weight_decay=0.01, grad_clip=1.0,
                 warmup_steps=None):
        if learning_rate is None:
            # small models tolerate (and demos need) a hotter schedule
            learning_rate = {"tiny": 2e-3, "small": 1e-3, "serve": 1e-3}.get(preset, 3e-4)
        if warmup_steps is None:
            warmup_steps = {"tiny": 10.0, "small": 20.0, "serve": 20.0}.get(preset, 100.0)
        self.warmup_steps = warmup_steps
        self.preset = preset
        self.hp = configs.PRESETS[preset]
        self.cfg = build_model_config(preset, moe=moe, rope=rope, kernel=kernel)
        self.model: CausalLM = self.cfg.instantiate()
        self.learning_rate = learning_rate
        self.weight_decay = weight_decay
        self.grad_clip = grad_clip
        # Deterministic flattening order for the manifest.
        example = jax.eval_shape(lambda: self.model.init(jax.random.PRNGKey(0)))
        leaves, treedef = jax.tree_util.tree_flatten(example)
        self.treedef = treedef
        self.param_specs = [
            ("/".join(str(k.key) for k in path), tuple(leaf.shape), str(leaf.dtype))
            for path, leaf in jax.tree_util.tree_flatten_with_path(example)[0]
        ]

    # -- state layout: [params..., m..., v...] + step scalar -----------------
    def init(self, seed: jnp.ndarray):
        """seed: i32 scalar -> flat train state tuple."""
        params = self.model.init(jax.random.PRNGKey(seed))
        leaves = jax.tree_util.tree_leaves(params)
        zeros = [jnp.zeros_like(l) for l in leaves]
        step = jnp.zeros((), jnp.int32)
        return tuple(leaves + zeros + [jnp.zeros_like(z) for z in zeros] + [step])

    def _unflatten_state(self, state):
        n = len(self.param_specs)
        params = jax.tree_util.tree_unflatten(self.treedef, state[:n])
        m = list(state[n : 2 * n])
        v = list(state[2 * n : 3 * n])
        step = state[3 * n]
        return params, m, v, step

    def loss_fn(self, params, tokens, targets):
        loss, metrics = self.model.loss(params, tokens, targets)
        return loss, metrics

    def train_step(self, *args):
        """(state..., tokens, targets) -> (new_state..., loss).

        AdamW with linear warmup and gradient-norm clipping; fused into one
        HLO program so the Rust hot loop is a single execute() per step.
        """
        state, tokens, targets = args[:-2], args[-2], args[-1]
        params, m, v, step = self._unflatten_state(state)
        (loss, _metrics), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
            params, tokens, targets
        )
        g_leaves = jax.tree_util.tree_leaves(grads)
        p_leaves = jax.tree_util.tree_leaves(params)
        # global grad-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in g_leaves))
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        step_f = step.astype(jnp.float32) + 1.0
        warmup = jnp.minimum(1.0, step_f / self.warmup_steps)
        lr = self.learning_rate * warmup
        b1, b2, eps = 0.9, 0.95, 1e-8
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(p_leaves, g_leaves, m, v):
            g = g * clip
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**step_f)
            vhat = vi / (1 - b2**step_f)
            upd = mhat / (jnp.sqrt(vhat) + eps) + self.weight_decay * p
            new_p.append(p - lr * upd)
            new_m.append(mi)
            new_v.append(vi)
        new_step = step + 1
        return tuple(new_p + new_m + new_v + [new_step, loss])

    def eval_loss(self, *args):
        """(params..., tokens, targets) -> (loss,). Forward only."""
        n = len(self.param_specs)
        params = jax.tree_util.tree_unflatten(self.treedef, args[:n])
        loss, _ = self.loss_fn(params, args[n], args[n + 1])
        return (loss,)

    # -- serving ------------------------------------------------------------
    def prefill(self, *args):
        """(params..., tokens [B,S], prompt_len [B]) ->
        (next_token [B], k_cache, v_cache [L,B,maxS,H,dh])."""
        n = len(self.param_specs)
        params = jax.tree_util.tree_unflatten(self.treedef, args[:n])
        tokens, prompt_len = args[n], args[n + 1]
        b, s = tokens.shape
        max_s = self.hp["max_seq_len"]
        logits, k, v = self.model._children["decoder"].prefill(params["decoder"], tokens)
        # gather logits at position prompt_len-1 per row
        last = jnp.take_along_axis(logits, (prompt_len - 1)[:, None, None], axis=1)[:, 0]
        next_token = jnp.argmax(last, axis=-1).astype(jnp.int32)
        # pad caches out to max_seq_len
        pad = max_s - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return next_token, k, v

    def decode(self, *args):
        """(params..., k_cache, v_cache, pos [B], token [B]) ->
        (next_token [B], k_cache, v_cache)."""
        n = len(self.param_specs)
        params = jax.tree_util.tree_unflatten(self.treedef, args[:n])
        k_cache, v_cache, pos, token = args[n], args[n + 1], args[n + 2], args[n + 3]
        logits, k_cache, v_cache = self.model._children["decoder"].decode_step(
            params["decoder"], token, pos, k_cache, v_cache
        )
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, k_cache, v_cache

    @staticmethod
    def insert_slot(full_k, full_v, one_k, one_v, slot):
        """Write a single-request cache (batch=1) into batch slot ``slot`` of
        a live decode cache — the continuous-batching admission op."""
        fk = jax.lax.dynamic_update_slice(full_k, one_k, (0, slot, 0, 0, 0))
        fv = jax.lax.dynamic_update_slice(full_v, one_v, (0, slot, 0, 0, 0))
        return fk, fv

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s, _ in self.param_specs)
