"""Fused RMSNorm as a Pallas kernel (secondary L1 kernel).

§7.2 highlights that "memory bound operations such as RMSNorm and RoPE
[are] fused without any hand-written kernels" by XLA on the AXLearn path —
PyTorch FSDP pays extra HBM traffic for them.  This kernel exists to
*quantify* that effect at the L1 level: one fused pass (read x, write y)
versus the unfused reference's multiple round trips, and to exercise a
second, memory-bound (non-MXU) kernel shape through the same
Pallas-interpret → HLO-text → PJRT pipeline.

Forward-only custom_vjp: the backward is expressed with jnp (norm backward
is cheap and fuses well; the paper's claim concerns the forward traffic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    """One block of rows: y = x / rms(x) * w, f32 accumulation."""
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * inv * w).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, weight, eps: float = 1e-6):
    """Fused RMSNorm over the trailing dim.

    x: [..., dim]; weight: [dim].  Matches ``ref.rmsnorm_ref``.
    """
    return _rmsnorm_fwd_impl(x, weight, eps)


def _rmsnorm_fwd_impl(x, weight, eps):
    orig_shape = x.shape
    dim = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    xf = x.reshape(rows, dim)
    # block over rows; the whole feature dim stays resident (dim*4B << VMEM)
    block_rows = min(256, rows)
    while rows % block_rows != 0:
        block_rows -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, dim), x.dtype),
        interpret=True,
    )(xf, weight)
    return out.reshape(orig_shape)


def _rmsnorm_fwd(x, weight, eps):
    return _rmsnorm_fwd_impl(x, weight, eps), (x, weight)


def _rmsnorm_bwd(eps, res, g):
    x, weight = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = x32 * inv
    gw = g32 * weight.astype(jnp.float32)
    # d xhat/dx backward for rms normalization
    dim = x.shape[-1]
    dx = inv * (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True))
    dw = jnp.sum((g32 * xhat).reshape(-1, dim), axis=0).astype(weight.dtype)
    return dx.astype(x.dtype), dw


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def hbm_traffic_model(rows: int, dim: int, elem_bytes: float, fused: bool) -> float:
    """Bytes moved for RMSNorm over [rows, dim] — the §7.2 fusion claim.

    Fused: read x once, read w, write y.  Unfused (separate square/mean/
    rsqrt/mul/scale ops materialized): ~3 extra round trips of x-sized
    intermediates.
    """
    base = rows * dim * elem_bytes * 2 + dim * elem_bytes
    if fused:
        return base
    return base + 3.0 * 2.0 * rows * dim * elem_bytes
