"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth that ``pytest python/tests`` checks the Pallas
kernels against.  They are deliberately written in the most direct way
possible (no tiling, no online softmax) so that a bug in the kernel cannot
be mirrored here.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference multi-head scaled dot-product attention.

    Args:
      q: [batch, heads, q_len, head_dim]
      k: [batch, heads, kv_len, head_dim]
      v: [batch, heads, kv_len, head_dim]
      causal: apply a causal mask (q position i attends to kv positions <= i,
        aligned at the end: query i corresponds to kv position
        ``kv_len - q_len + i``).
      scale: softmax scale; defaults to 1/sqrt(head_dim).

    Returns:
      [batch, heads, q_len, head_dim]
    """
    *_, q_len, head_dim = q.shape
    kv_len = k.shape[-2]
    if scale is None:
        scale = 1.0 / (head_dim**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
        k_pos = jnp.arange(kv_len)[None, :]
        mask = k_pos <= q_pos
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def attention_ref_lse(q, k, v, *, causal=True, scale=None):
    """Like :func:`attention_ref` but also returns log-sum-exp per query.

    Used to validate the residuals the flash kernel saves for its backward
    pass.  Returns ``(out, lse)`` with ``lse: [batch, heads, q_len]``.
    """
    *_, q_len, head_dim = q.shape
    kv_len = k.shape[-2]
    if scale is None:
        scale = 1.0 / (head_dim**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
        k_pos = jnp.arange(kv_len)[None, :]
        mask = k_pos <= q_pos
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    unnorm = jnp.exp(logits - m)
    denom = unnorm.sum(axis=-1, keepdims=True)
    lse = (m + jnp.log(denom)).squeeze(-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", (unnorm / denom).astype(v.dtype), v)
    return out, lse


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Reference RMSNorm over the trailing dimension."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * (1.0 / jnp.sqrt(var + eps))).astype(x.dtype) * weight


def rope_ref(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Reference rotary position embedding.

    Args:
      x: [..., seq, head_dim] with head_dim even.
      positions: [seq] integer positions.
    """
    head_dim = x.shape[-1]
    assert head_dim % 2 == 0
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [seq, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    return jnp.concatenate([rot1, rot2], axis=-1).astype(x.dtype)
