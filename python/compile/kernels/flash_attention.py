"""FlashAttention as a Pallas kernel (TPU-shaped, interpret-mode on CPU).

This is the Layer-1 compute hot-spot of the stack.  The paper (AXLearn §4.2)
dispatches FlashAttention implementations per backend — cuDNN on GPU, NKI on
Trainium, SplashAttention/Pallas on TPU.  We implement the TPU-shaped Pallas
variant:

* CUDA threadblock tiling       -> Pallas grid over (batch*heads, q-blocks)
* shared-memory staging         -> VMEM-sized blocks selected via BlockSpec
* tensor-core WMMA              -> MXU-shaped ``jnp.dot`` on (block_q, d) tiles
* online softmax (FA-2)         -> f32 running max / denominator carried in
                                   the fori_loop over k-blocks

The backward pass is the FlashAttention-2 backward: the forward saves only
the per-row log-sum-exp (LSE); the backward recomputes attention
probabilities block-by-block and accumulates dq (one kernel, grid over
q-blocks) and dk/dv (a second kernel, grid over k-blocks).

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-calls produced by real TPU lowering.  Correctness is checked
against ``ref.py`` by ``python/tests/test_flash_attention.py``; TPU
VMEM/MXU-utilization estimates live in ``rust/src/perfmodel/kernels.rs``.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default block sizes.  (128, 128) tiles the MXU (128x128 systolic array)
# exactly; a (block_q=128, d<=128) q-tile plus (block_k=128, d) k/v-tiles and
# the f32 accumulator fit comfortably in the ~16 MiB VMEM budget per core.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that is <= preferred (kernels require exact
    tiling; the wrapper pads first, so ``n`` is already a multiple of 8
    whenever it exceeds 8)."""
    b = min(preferred, n)
    while n % b != 0:
        b -= 1
    return b


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, causal, block_k, kv_len_actual, q_len_actual, q_offset
):
    """Forward kernel for one (batch*head, q-block) grid cell.

    Refs (VMEM blocks):
      q_ref:   [1, block_q, d]
      k_ref:   [1, kv_len, d]   (streamed block_k at a time via pl.ds)
      v_ref:   [1, kv_len, d]
      o_ref:   [1, block_q, d]
      lse_ref: [1, block_q]
    """
    block_q = q_ref.shape[1]
    d = q_ref.shape[2]
    kv_len = k_ref.shape[1]
    qi = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, d]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
    valid_q = q_pos < (q_len_actual + q_offset)

    num_kb = kv_len // block_k

    def body(j, carry):
        acc, m_i, l_i = carry
        k_blk = k_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)  # [bq, bk]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < kv_len_actual
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        # Padded q rows attend to key 0 only: keeps the softmax finite; the
        # wrapper slices these rows away.
        mask = jnp.where(valid_q[:, None], mask, (k_pos == 0)[None, :])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    l_safe = jnp.maximum(l_i, 1e-30)
    o_ref[0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m_i + jnp.log(l_safe)


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, scale, causal, block_k, kv_len_actual, q_offset
):
    """Backward dq for one (batch*head, q-block) grid cell (FA-2 eq. 4)."""
    block_q = q_ref.shape[1]
    d = q_ref.shape[2]
    kv_len = k_ref.shape[1]
    qi = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset

    num_kb = kv_len // block_k

    def body(j, dq):
        k_blk = k_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < kv_len_actual
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        # exp(NEG_INF - lse) underflows to 0 for masked entries; guard the
        # wholly-masked (padded) rows where lse itself is degenerate.
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jnp.dot(do, v_blk.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jnp.dot(ds, k_blk, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, num_kb, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, scale, causal, block_q, kv_len_actual, q_offset
):
    """Backward dk/dv for one (batch*head, k-block) grid cell."""
    block_k = k_ref.shape[1]
    d = k_ref.shape[2]
    q_len = q_ref.shape[1]
    ki = pl.program_id(1)

    k_blk = k_ref[0].astype(jnp.float32)
    v_blk = v_ref[0].astype(jnp.float32)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    k_valid = k_pos < kv_len_actual

    num_qb = q_len // block_q

    def body(i, carry):
        dk, dv = carry
        q = q_ref[0, pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        do = do_ref[0, pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        lse = lse_ref[0, pl.ds(i * block_q, block_q)]
        delta = delta_ref[0, pl.ds(i * block_q, block_q)]
        q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        mask = k_valid[None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v_blk.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        return dk, dv

    zeros = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, num_qb, body, (zeros, zeros))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention(
    q,
    k,
    v,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
):
    """FlashAttention over [batch, heads, seq, head_dim] tensors.

    Matches :func:`ref.attention_ref` numerically (f32 accumulation) while
    streaming K/V through VMEM-sized blocks.  Differentiable via the FA-2
    backward kernels registered as its custom VJP.
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, scale, block_q, block_k)
    return out


def _flash_fwd_impl(q, k, v, causal, scale, block_q, block_k):
    b, h, q_len, d = q.shape
    kv_len = k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    q_offset = kv_len - q_len  # end-aligned causal masking

    qf = q.reshape(b * h, q_len, d)
    kf = k.reshape(b * h, kv_len, d)
    vf = v.reshape(b * h, kv_len, d)

    # Pad sequence dims to a multiple of 8 so block sizes can tile exactly.
    qf = _pad_to(qf, 1, 8)
    kf = _pad_to(kf, 1, 8)
    vf = _pad_to(vf, 1, 8)
    pq_len, pkv_len = qf.shape[1], kf.shape[1]
    bq = _pick_block(pq_len, block_q)
    bk = _pick_block(pkv_len, block_k)
    num_q = pq_len // bq

    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        causal=causal,
        block_k=bk,
        kv_len_actual=kv_len,
        q_len_actual=q_len,
        q_offset=q_offset,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=(b * h, num_q),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, pkv_len, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, pkv_len, d), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, bq), lambda bh, i: (bh, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, pq_len, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, pq_len), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf)
    out = out[:, :q_len, :].reshape(b, h, q_len, d)
    return out, lse


def _flash_fwd_vjp(q, k, v, causal, scale, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, scale, block_q, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd_vjp(causal, scale, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    b, h, q_len, d = q.shape
    kv_len = k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    q_offset = kv_len - q_len

    # delta_i = rowsum(dO_i * O_i)   (FA-2 Alg. 2 line 4; elementwise, cheap)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    qf = _pad_to(q.reshape(b * h, q_len, d), 1, 8)
    kf = _pad_to(k.reshape(b * h, kv_len, d), 1, 8)
    vf = _pad_to(v.reshape(b * h, kv_len, d), 1, 8)
    dof = _pad_to(dout.reshape(b * h, q_len, d), 1, 8)
    deltaf = _pad_to(delta.reshape(b * h, q_len), 1, 8)
    # lse is already padded to pq_len by the forward impl.
    pq_len, pkv_len = qf.shape[1], kf.shape[1]
    bq = _pick_block(pq_len, block_q)
    bk = _pick_block(pkv_len, block_k)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, scale=scale, causal=causal, block_k=bk, kv_len_actual=kv_len, q_offset=q_offset
        ),
        grid=(b * h, pq_len // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, pkv_len, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, pkv_len, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, bq), lambda bh, i: (bh, i)),
            pl.BlockSpec((1, bq), lambda bh, i: (bh, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, pq_len, d), q.dtype),
        interpret=True,
    )(qf, kf, vf, dof, lse, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, scale=scale, causal=causal, block_q=bq, kv_len_actual=kv_len, q_offset=q_offset
        ),
        grid=(b * h, pkv_len // bk),
        in_specs=[
            pl.BlockSpec((1, pq_len, d), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, pq_len, d), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, pq_len), lambda bh, j: (bh, 0)),
            pl.BlockSpec((1, pq_len), lambda bh, j: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, j: (bh, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, pkv_len, d), k.dtype),
            jax.ShapeDtypeStruct((b * h, pkv_len, d), v.dtype),
        ],
        interpret=True,
    )(qf, kf, vf, dof, lse, deltaf)

    dq = dq[:, :q_len, :].reshape(b, h, q_len, d)
    dk = dk[:, :kv_len, :].reshape(b, h, kv_len, d)
    dv = dv[:, :kv_len, :].reshape(b, h, kv_len, d)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd_vjp)


def flash_attention_with_lse(q, k, v, *, causal=True, scale=None):
    """Forward-only variant that also returns the LSE (for tests)."""
    out, lse = _flash_fwd_impl(q, k, v, causal, scale, DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K)
    b, h, q_len, _ = q.shape
    return out, lse[:, :q_len].reshape(b, h, q_len)
