//! End-to-end validation driver (DESIGN.md §5 "E2E"): train the small
//! (~3.7M-param) model for hundreds of steps on the synthetic Markov
//! corpus, logging the loss curve to examples/out/loss_small.csv, with
//! async checkpointing + SDC sweeps enabled; then smoke the ~91M-param
//! base100m artifact for a few steps to prove the full-scale path.
//!
//! Entirely Python-free at runtime: every FLOP runs through the AOT HLO
//! artifacts on the PJRT CPU client.
//!
//! Env knobs: E2E_STEPS (default 300), E2E_100M_STEPS (default 2; 0 skips).

use std::sync::Arc;

use axlearn::checkpoint::CheckpointerOptions;
use axlearn::runtime::{Manifest, RuntimeClient};
use axlearn::trainer::{train, SyntheticCorpus, TrainerOptions};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    let out_dir = axlearn::repo_root().join("examples/out");
    std::fs::create_dir_all(&out_dir)?;

    // ---- phase 1: small model, full run ---------------------------------
    let steps = env_u64("E2E_STEPS", 300);
    let art = manifest.get("small_train_step")?;
    let vocab = art.hyper["vocab_size"] as usize;
    let mut corpus = SyntheticCorpus::new(
        axlearn::trainer::input::CorpusKind::Markov,
        vocab,
        art.batch,
        art.seq,
        42,
    );
    println!(
        "[e2e] training `small` ({}x{} batch, vocab {vocab}) for {steps} steps",
        art.batch, art.seq
    );
    let t0 = std::time::Instant::now();
    let out = train(
        client.clone(),
        &manifest,
        &mut corpus,
        &TrainerOptions {
            artifact: "small".into(),
            max_steps: steps,
            checkpoint_every: 100,
            checkpoint: CheckpointerOptions {
                dir: out_dir.join("ckpt_small"),
                ..Default::default()
            },
            sdc_every: 100,
            ..Default::default()
        },
    )?;
    let csv = out_dir.join("loss_small.csv");
    out.metrics.write_csv(&csv)?;
    println!(
        "[e2e] small: loss {:.3} -> {:.3} (corpus floor ~{:.2} nats, uniform would be {:.2})",
        out.first_loss,
        out.final_loss,
        corpus.entropy_floor(),
        (vocab as f64).ln()
    );
    println!("[e2e] loss curve: {}", out.metrics.sparkline(60));
    println!(
        "[e2e] {:.0} tokens/s on 1 CPU core | goodput {:.1}% | wrote {}",
        out.metrics.tokens_per_second(),
        out.goodput.goodput() * 100.0,
        csv.display()
    );
    assert!(
        (out.final_loss as f64) < (vocab as f64).ln() * 0.75,
        "model failed to learn corpus structure"
    );

    // ---- phase 2: ~100M smoke --------------------------------------------
    let steps_100m = env_u64("E2E_100M_STEPS", 2);
    if steps_100m > 0 {
        let art = manifest.get("base100m_train_step")?;
        println!(
            "\n[e2e] smoking `base100m` (~91M params, {}x{} batch) for {steps_100m} steps — compiling...",
            art.batch, art.seq
        );
        let mut corpus100 = SyntheticCorpus::new(
            axlearn::trainer::input::CorpusKind::Markov,
            art.hyper["vocab_size"] as usize,
            art.batch,
            art.seq,
            7,
        );
        let out100 = train(
            client,
            &manifest,
            &mut corpus100,
            &TrainerOptions {
                artifact: "base100m".into(),
                max_steps: steps_100m,
                ..Default::default()
            },
        )?;
        println!(
            "[e2e] base100m: loss {:.3} -> {:.3} over {} steps ({:.1}s/step)",
            out100.first_loss,
            out100.final_loss,
            out100.final_step,
            out100.metrics.records.last().map(|r| r.step_time_s).unwrap_or(0.0)
        );
        assert!(out100.final_loss.is_finite());
    }
    println!("\n[e2e] total wall time {:.0}s", t0.elapsed().as_secs_f64());
    Ok(())
}
