//! Figure 1 live: the ~10-line MoE swap.
//!
//! Replaces every FeedForward in the trainer config with MoE via config
//! traversal — no model-code changes — then trains BOTH variants on their
//! AOT artifacts and shows the golden-config diff is localized.

use std::sync::Arc;

use axlearn::composer::materialize;
use axlearn::config::mesh_rules::paper_appendix_a_rules;
use axlearn::config::registry::{default_config, trainer_for_preset};
use axlearn::config::{config_diff, replace_config, Value};
use axlearn::runtime::{Manifest, RuntimeClient};
use axlearn::trainer::{train, SyntheticCorpus, TrainerOptions};

fn main() -> anyhow::Result<()> {
    let dense_cfg = trainer_for_preset("tiny")?;

    // ---- the paper's 10-line snippet, verbatim shape -------------------
    let mut moe_cfg = dense_cfg.clone();
    let n = replace_config(&mut moe_cfg, "FeedForward", &|old| {
        default_config("MoE").unwrap()
            .with("input_dim", old.get("input_dim").unwrap().clone())
            .with("hidden_dim", old.get("hidden_dim").unwrap().clone())
            .with("num_experts", Value::Int(4))
            .with("top_k", Value::Int(2))
    });
    // ---------------------------------------------------------------------
    println!("replaced {n} FeedForward config(s) with MoE");

    let (only_dense, only_moe) = config_diff(&dense_cfg, &moe_cfg);
    println!("\nconfig diff ({} - / {} + lines, all under feed_forward):", only_dense.len(), only_moe.len());
    for l in only_moe.iter().take(6) {
        println!("  + {l}");
    }
    assert!(only_moe.iter().all(|l| l.contains("feed_forward")));

    let rules = paper_appendix_a_rules();
    let dense_plan = materialize(&dense_cfg, "cpu-local", 1, &rules)?;
    let moe_plan = materialize(&moe_cfg, "cpu-local", 1, &rules)?;
    println!("\nartifacts: dense={} moe={}", dense_plan.artifact, moe_plan.artifact);

    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    for plan in [&dense_plan, &moe_plan] {
        let art = manifest.get(&format!("{}_train_step", plan.artifact))?;
        let mut corpus = SyntheticCorpus::new(
            axlearn::trainer::input::CorpusKind::Markov,
            art.hyper["vocab_size"] as usize,
            art.batch,
            art.seq,
            0,
        );
        let out = train(
            client.clone(),
            &manifest,
            &mut corpus,
            &TrainerOptions {
                artifact: plan.artifact.clone(),
                max_steps: 25,
                ..Default::default()
            },
        )?;
        println!(
            "{:>9}: params thru artifact, loss {:.3} -> {:.3}  {}",
            plan.artifact,
            out.first_loss,
            out.final_loss,
            out.metrics.sparkline(30)
        );
    }
    Ok(())
}
