//! Quickstart: compose a trainer config, materialize it, and train the
//! tiny model for a few steps on the CPU PJRT runtime.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use std::sync::Arc;

use axlearn::composer::materialize;
use axlearn::config::mesh_rules::paper_appendix_a_rules;
use axlearn::config::registry::trainer_for_preset;
use axlearn::runtime::{Manifest, RuntimeClient};
use axlearn::trainer::{train, SyntheticCorpus, TrainerOptions};

fn main() -> anyhow::Result<()> {
    // 1. Compose a config (hierarchical, strictly encapsulated — §4.1).
    let trainer_cfg = trainer_for_preset("tiny")?;
    println!("-- golden serialization (first 12 lines) --");
    for line in axlearn::config::to_golden_lines(&trainer_cfg).iter().take(12) {
        println!("  {line}");
    }

    // 2. Materialize for this target (local CPU): artifact + plan.
    let plan = materialize(&trainer_cfg, "cpu-local", 1, &paper_appendix_a_rules())?;
    println!("\nplan: artifact={} kernel={}", plan.artifact, plan.kernel_backend);

    // 3. Train on the AOT artifact — Python is NOT running.
    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    let art = manifest.get(&format!("{}_train_step", plan.artifact))?;
    let mut corpus = SyntheticCorpus::new(
        axlearn::trainer::input::CorpusKind::Markov,
        art.hyper["vocab_size"] as usize,
        art.batch,
        art.seq,
        0,
    );
    let out = train(
        client,
        &manifest,
        &mut corpus,
        &TrainerOptions {
            artifact: plan.artifact.clone(),
            max_steps: 30,
            ..Default::default()
        },
    )?;
    println!(
        "\ntrained 30 steps: loss {:.3} -> {:.3} | {:.0} tok/s",
        out.first_loss,
        out.final_loss,
        out.metrics.tokens_per_second()
    );
    println!("loss: {}", out.metrics.sparkline(40));
    Ok(())
}
