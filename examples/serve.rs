//! Serving demo (§6 / Table 4 / Figure 5 mechanism): load the `serve`
//! artifacts, run a ShareGPT-like workload through BOTH the continuous-
//! batching engine and the vLLM-style static baseline, and report
//! TTFT/TPOT/throughput side by side.

use std::sync::Arc;

use axlearn::runtime::{Manifest, RuntimeClient, ServeSession};
use axlearn::serving::baseline::{StaticBatchEngine, StaticBatchOptions};
use axlearn::serving::{BatcherOptions, Engine, Workload, WorkloadOptions};

fn main() -> anyhow::Result<()> {
    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    let workload = Workload::sharegpt_like(WorkloadOptions {
        num_requests: 16,
        request_rate: 2.0,
        max_input_len: 120,
        max_output_len: 24,
        vocab: 2048,
        seed: 7,
    });
    println!(
        "serving {} requests (ShareGPT-like lengths, Poisson arrivals @2/s)\n",
        workload.requests.len()
    );

    let session = ServeSession::open(client.clone(), &manifest, "serve")?;
    let engine = Engine::new(
        session,
        BatcherOptions {
            slots: 8,
            kv_pages: 2048,
            page_tokens: 16,
        },
    );
    let ax = engine.run(&workload)?;
    println!(
        "AXLearn continuous batching: TTFT {:.0} ms | TPOT {:.1} ms | {:.0} tok/s | occupancy {:.1}/8",
        ax.stats.mean_ttft_s * 1e3,
        ax.stats.mean_tpot_s * 1e3,
        ax.stats.throughput_tok_s,
        ax.mean_batch_occupancy
    );

    let session2 = ServeSession::open(client, &manifest, "serve")?;
    let baseline = StaticBatchEngine::new(session2, StaticBatchOptions::default());
    let vl = baseline.run(&workload)?;
    println!(
        "vLLM-style static batching: TTFT {:.0} ms | TPOT {:.1} ms | {:.0} tok/s | {} compile stalls, {} wasted rows",
        vl.stats.mean_ttft_s * 1e3,
        vl.stats.mean_tpot_s * 1e3,
        vl.stats.throughput_tok_s,
        vl.compile_stalls,
        vl.wasted_decode_rows
    );
    println!(
        "\nspeedups (continuous over static): TTFT x{:.1}, TPOT x{:.1}, throughput x{:.1}",
        vl.stats.mean_ttft_s / ax.stats.mean_ttft_s,
        vl.stats.mean_tpot_s / ax.stats.mean_tpot_s,
        ax.stats.throughput_tok_s / vl.stats.throughput_tok_s
    );
    Ok(())
}
