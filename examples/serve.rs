//! Serving demo (§6 / Table 4 / Figure 5 mechanism): run a ShareGPT-like
//! workload through BOTH the continuous-batching engine and the
//! vLLM-style static baseline over the same `ComputeBackend` artifacts,
//! then scale out to a routed multi-replica fleet with hot-swap.

use std::sync::Arc;

use axlearn::runtime::{ComputeBackend, Manifest, MockBackend, RuntimeClient, ServeSession};
use axlearn::serving::baseline::{StaticBatchEngine, StaticBatchOptions};
use axlearn::serving::{
    BatcherOptions, Engine, FailureEvent, ReplicaRouter, RouterOptions, Workload, WorkloadOptions,
};

fn main() -> anyhow::Result<()> {
    let workload = Workload::sharegpt_like(WorkloadOptions {
        num_requests: 16,
        request_rate: 2.0,
        max_input_len: 120,
        max_output_len: 24,
        vocab: 2048,
        seed: 7,
    });
    println!(
        "serving {} requests (ShareGPT-like lengths, Poisson arrivals @2/s)\n",
        workload.requests.len()
    );

    // ---- fleet demo (mock backend: no artifacts needed) ----------------
    let fleet_workload = Workload::sharegpt_like(WorkloadOptions {
        num_requests: 64,
        request_rate: f64::INFINITY,
        max_input_len: 120,
        max_output_len: 24,
        vocab: 2048,
        seed: 7,
    });
    for replicas in [1usize, 2, 4] {
        let backends: Vec<Box<dyn ComputeBackend>> = (0..replicas + 1)
            .map(|_| Box::new(MockBackend::default()) as Box<dyn ComputeBackend>)
            .collect();
        let mut router = ReplicaRouter::new(
            backends,
            RouterOptions {
                replicas,
                spares: 1,
                batcher: BatcherOptions::default(),
            },
        )?;
        let report = router.run(
            &fleet_workload,
            &[FailureEvent {
                replica: 0,
                at_s: 0.05,
            }],
        )?;
        println!(
            "fleet x{replicas} (+1 spare, replica 0 fails at 50ms): {:>7.0} tok/s | {} rerouted | {} swap(s)",
            report.stats.throughput_tok_s, report.reroutes, report.swaps
        );
    }
    println!();

    // ---- real-substrate comparison (needs `make artifacts`) ------------
    let client = Arc::new(RuntimeClient::cpu()?);
    let manifest = Manifest::load(&axlearn::artifacts_dir())?;
    let session = ServeSession::open(client.clone(), &manifest, "serve")?;
    let mut engine = Engine::from_session(
        session,
        BatcherOptions {
            slots: 8,
            kv_pages: 2048,
            page_tokens: 16,
            ..Default::default()
        },
    )?;
    let ax = engine.run(&workload)?;
    println!(
        "AXLearn continuous batching: TTFT {:.0} ms | TPOT {:.1} ms | {:.0} tok/s | occupancy {:.1}/8",
        ax.stats.mean_ttft_s * 1e3,
        ax.stats.mean_tpot_s * 1e3,
        ax.stats.throughput_tok_s,
        ax.mean_batch_occupancy
    );

    let session2 = ServeSession::open(client, &manifest, "serve")?;
    let mut baseline = StaticBatchEngine::from_session(session2, StaticBatchOptions::default())?;
    let vl = baseline.run(&workload)?;
    println!(
        "vLLM-style static batching: TTFT {:.0} ms | TPOT {:.1} ms | {:.0} tok/s | {} compile stalls, {} wasted rows",
        vl.stats.mean_ttft_s * 1e3,
        vl.stats.mean_tpot_s * 1e3,
        vl.stats.throughput_tok_s,
        vl.compile_stalls,
        vl.wasted_decode_rows
    );
    println!(
        "\nspeedups (continuous over static): TTFT x{:.1}, TPOT x{:.1}, throughput x{:.1}",
        vl.stats.mean_ttft_s / ax.stats.mean_ttft_s,
        vl.stats.mean_tpot_s / ax.stats.mean_tpot_s,
        ax.stats.throughput_tok_s / vl.stats.throughput_tok_s
    );
    Ok(())
}
