//! Heterogeneous targets (§4.2 + Appendix A): the SAME experiment config
//! materialized for TPU v5e, H100, v5p, and Trainium2 — mesh rules apply
//! per-platform strategies, then the AOT compile-check (§4.2) validates
//! memory/utilization for each, all from this single CPU host.

use axlearn::composer::{aot_compile_check, materialize};
use axlearn::config::mesh_rules::paper_appendix_a_rules;
use axlearn::config::registry::trainer_for_preset;
use axlearn::perfmodel::chips;

fn main() -> anyhow::Result<()> {
    let cfg = trainer_for_preset("small")?; // ONE experiment config
    let rules = paper_appendix_a_rules();
    let targets = [
        ("tpu-v5e-256-4", 1024usize),
        ("gpu-H100-32", 256),
        ("tpu-v5p-512", 256),
        ("trn2-16xlarge", 1024),
    ];
    println!(
        "{:<16} {:>22} {:>8} {:>12} {:>10} {:>8} {:>9}\n",
        "target", "strategy", "quant", "remat", "kernel", "MFU", "HBM(GB)"
    );
    for (target, n) in targets {
        let plan = materialize(&cfg, target, n, &rules)?;
        let chip = chips::by_instance_type(target).unwrap();
        let report = aot_compile_check(&plan, &chip, None)?;
        println!(
            "{:<16} {:>22} {:>8} {:>12} {:>10} {:>7.1}% {:>9.2}",
            target,
            format!(
                "d{}/f{}/t{}",
                plan.strategy.data, plan.strategy.fsdp, plan.strategy.tensor
            ),
            plan.quantization,
            plan.remat_policy,
            plan.kernel_backend,
            report.predicted_mfu * 100.0,
            report.hbm_used_bytes / 1e9,
        );
    }
    println!("\n(no model code changed between targets — only mesh rules applied)");
    Ok(())
}
